//! Deployment plans: the joint spatial/temporal configuration GACER
//! searches over, its multi-device sharding, and its compilation to
//! simulator streams.
//!
//! A [`DeploymentPlan`] carries the paper's three decision structures:
//! the decomposition `mask` + `list_B` per operator (§4.2) and the pointer
//! matrix `Matrix_P` (§4.3). [`TenantSet::compile`] lowers tenants + plan
//! into per-stream [`SimOp`] sequences, inserting the chunk/concat overhead
//! operators that batch decomposition costs and assigning each op its
//! segment (cluster) index from the pointer positions.
//!
//! For multi-GPU deployments the plan grows a **device dimension**: a
//! [`Placement`] assigns every tenant slot to one device (cost-model-driven
//! bin-packing under a [`PlacementObjective`] — load balance, or
//! interference-aware co-location scored on the occupancy curves), and a
//! [`ShardedDeploymentPlan`] carries one independently searched
//! [`DeploymentPlan`] per device. GACER's regulation stays strictly
//! per-GPU — sharding decides *where* a tenant runs, the per-shard plan
//! decides *how* it is regulated there.
//!
//! ```
//! use gacer::models::zoo;
//! use gacer::plan::{DeploymentPlan, Placement, ShardedDeploymentPlan, TenantSet};
//! use gacer::profile::{CostModel, Platform};
//!
//! let tenants = zoo::build_combo(&["Alex", "R18"]);
//! let set = TenantSet::new(tenants, CostModel::new(Platform::titan_v()));
//! // Single device: the classic plan shape.
//! let plan = DeploymentPlan::unregulated(set.len());
//! plan.validate(&set.tenants).unwrap();
//! // Two devices: a placement plus one plan per shard.
//! let placement = Placement::balanced(&set, 2);
//! let sharded = ShardedDeploymentPlan::unregulated(placement);
//! sharded.validate(&set.tenants).unwrap();
//! assert_eq!(sharded.n_devices(), 2);
//! ```

use std::collections::BTreeMap;


use crate::dfg::{Dfg, OpId, OpKind};
use crate::error::{Error, Result};
use crate::gpu::{SimOp, SimStage};
use crate::profile::{CostModel, DevicePool};
use crate::temporal::PointerMatrix;

/// Per-tenant batch-decomposition choices: `op id -> list_B` (Eq. 5).
/// An absent entry is `mask(O) = 0` (no decomposition).
pub type ChunkMap = BTreeMap<OpId, Vec<usize>>;

/// The joint spatial + temporal deployment configuration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DeploymentPlan {
    /// Spatial: one chunk map per tenant (the mask + `list_B` of §4.2).
    pub chunking: Vec<ChunkMap>,
    /// Temporal: the pointer matrix `Matrix_P` of §4.3.
    pub pointers: PointerMatrix,
}

impl DeploymentPlan {
    /// The unregulated plan (Stream-Parallel's configuration).
    pub fn unregulated(n_tenants: usize) -> Self {
        DeploymentPlan {
            chunking: vec![ChunkMap::new(); n_tenants],
            pointers: PointerMatrix::empty(n_tenants),
        }
    }

    /// Total number of decomposed operators (the mask's popcount).
    pub fn decomposed_ops(&self) -> usize {
        self.chunking.iter().map(|m| m.len()).sum()
    }

    /// Validate against a tenant set: chunk lists must sum to the op's
    /// batch (Eq. 5's constraint) and pointer positions must be in range.
    pub fn validate(&self, tenants: &[Dfg]) -> Result<()> {
        let bad = |m: String| Err(Error::InvalidPlan(m));
        if self.chunking.len() != tenants.len() {
            return bad(format!(
                "plan has {} chunk maps for {} tenants",
                self.chunking.len(),
                tenants.len()
            ));
        }
        for (ti, (map, dfg)) in self.chunking.iter().zip(tenants).enumerate() {
            for (&op, list_b) in map {
                let Some(o) = dfg.ops.get(op) else {
                    return bad(format!("tenant {ti}: chunk map references op {op}"));
                };
                if list_b.is_empty() || list_b.iter().any(|&b| b == 0) {
                    return bad(format!("tenant {ti} op {op}: empty/zero chunk"));
                }
                let sum: usize = list_b.iter().sum();
                if sum != o.batch {
                    return bad(format!(
                        "tenant {ti} op {op}: list_B sums to {sum}, batch is {}",
                        o.batch
                    ));
                }
                if !o.chunkable() && list_b.len() > 1 {
                    return bad(format!("tenant {ti} op {op}: not chunkable"));
                }
            }
        }
        self.pointers.validate(tenants)
    }

    /// Grow the plan for a newly admitted tenant: an empty chunk map and a
    /// pointer list seeded with `n_pointers` evenly spread positions (the
    /// paper keeps `|P|` equal across tenants, so an incremental re-search
    /// starts the newcomer at the deployment's current pointer level).
    pub fn push_tenant(&mut self, dfg_len: usize, n_pointers: usize) {
        self.chunking.push(ChunkMap::new());
        self.pointers.push_tenant(seeded_pointers(dfg_len, n_pointers));
    }

    /// Insert a tenant at local slot `at` (a migrated tenant's global slot
    /// can fall anywhere in the destination device's ascending local
    /// order, unlike an admission, which always appends). Seeded like
    /// [`DeploymentPlan::push_tenant`].
    pub fn insert_tenant(&mut self, at: usize, dfg_len: usize, n_pointers: usize) {
        self.chunking.insert(at, ChunkMap::new());
        self.pointers.insert_tenant(at, seeded_pointers(dfg_len, n_pointers));
    }

    /// Drop tenant `i`'s chunk map and pointer list (eviction).
    pub fn remove_tenant(&mut self, i: usize) {
        self.chunking.remove(i);
        self.pointers.remove_tenant(i);
    }

    /// Plan diff: the tenant slots whose regulation actually changed
    /// between `old` and `self` — a different chunk map or pointer list
    /// (slots present in only one plan count as changed). Unchanged slots
    /// lower to bit-identical serving specs, which is what lets a live
    /// re-deployment skip untouched tenants.
    ///
    /// ```
    /// use gacer::plan::DeploymentPlan;
    ///
    /// let old = DeploymentPlan::unregulated(3);
    /// let mut new = old.clone();
    /// new.pointers.set_list(1, vec![4]);
    /// assert_eq!(new.changed_tenants(&old), vec![1]);
    /// assert!(old.changed_tenants(&old).is_empty());
    /// ```
    pub fn changed_tenants(&self, old: &DeploymentPlan) -> Vec<usize> {
        let n = self.chunking.len().max(old.chunking.len());
        (0..n)
            .filter(|&i| {
                self.chunking.get(i) != old.chunking.get(i)
                    || self.pointers.list(i) != old.pointers.list(i)
            })
            .collect()
    }
}

/// Evenly spread pointer positions for a tenant joining a deployment at
/// pointer level `n_pointers`. A DFG with fewer than 2 ops has no legal
/// pointer position (valid range is `1..len`): it joins as one segment.
fn seeded_pointers(dfg_len: usize, n_pointers: usize) -> Vec<usize> {
    if dfg_len < 2 {
        Vec::new()
    } else {
        (1..=n_pointers)
            .map(|j| (j * dfg_len / (n_pointers + 1)).clamp(1, dfg_len - 1))
            .collect()
    }
}

/// The objective [`Placement`] construction optimizes across devices.
///
/// [`LoadBalance`](PlacementObjective::LoadBalance) is the classic LPT
/// bin-packing on summed serial latency. But load balance is blind to
/// *contention*: two tenants whose summed per-phase `W(O^B)` blows past
/// the SM pool slow each other down however evenly the latency totals are
/// spread. [`InterferenceAware`](PlacementObjective::InterferenceAware)
/// prices that with the cost model's occupancy curves
/// ([`CostModel::colocation_slowdown`]) and minimizes the max per-device
/// `load × predicted slowdown`, so two pool-saturating tenants are placed
/// apart even when raw load balance would pair them (VELTAIR-style
/// interference-aware co-location).
///
/// Both interference objectives score `load × predicted slowdown`;
/// they differ in the slowdown model.
/// [`InterferenceAware`](PlacementObjective::InterferenceAware) is
/// occupancy-only — blind to memory: two bandwidth-saturating,
/// low-occupancy tenants look free to it.
/// [`MemoryAware`](PlacementObjective::MemoryAware) scores the full
/// two-dimensional roofline ([`crate::profile::roofline_slowdown`]:
/// per phase, the max of SM overflow and bandwidth oversubscription)
/// and additionally enforces the device HBM capacity during greedy
/// construction, refinement, and admission.
///
/// ```
/// use gacer::plan::PlacementObjective;
///
/// assert_eq!(PlacementObjective::parse("balanced"),
///            Some(PlacementObjective::LoadBalance));
/// assert_eq!(PlacementObjective::parse("interference"),
///            Some(PlacementObjective::InterferenceAware));
/// assert_eq!(PlacementObjective::parse("memory"),
///            Some(PlacementObjective::MemoryAware));
/// assert!(PlacementObjective::parse("magic").is_none());
/// assert_eq!(PlacementObjective::default(), PlacementObjective::LoadBalance);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementObjective {
    /// Equalize summed serial latency per device (LPT bin-packing).
    #[default]
    LoadBalance,
    /// Minimize the max per-device `load × predicted co-location
    /// slowdown` over the **occupancy** curves only (greedy seeding +
    /// local move refinement).
    InterferenceAware,
    /// Minimize the max per-device `load × predicted slowdown` over the
    /// two-dimensional compute+memory roofline, under the device HBM
    /// capacity constraint.
    MemoryAware,
}

impl PlacementObjective {
    /// Parse a CLI spelling (`balanced` | `interference` | `memory`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "balanced" | "load-balance" | "lpt" => Some(Self::LoadBalance),
            "interference" | "interference-aware" => Some(Self::InterferenceAware),
            "memory" | "memory-aware" => Some(Self::MemoryAware),
            _ => None,
        }
    }

    /// Display name (`LoadBalance` / `InterferenceAware` / `MemoryAware`).
    pub fn label(&self) -> &'static str {
        match self {
            Self::LoadBalance => "LoadBalance",
            Self::InterferenceAware => "InterferenceAware",
            Self::MemoryAware => "MemoryAware",
        }
    }
}

/// Pre-sampled interference-scoring context: one serial-latency weight
/// and one occupancy timeline ([`CostModel::occupancy_profile`]) per
/// tenant slot — plus, for the memory-aware objective, a bandwidth
/// timeline ([`CostModel::bandwidth_profile`]), an HBM footprint per
/// slot, and the device capacity — computed **once** per placement
/// decision and reused across every candidate group the search scores.
struct InterferenceCtx {
    weights: Vec<f64>,
    profiles: Vec<Vec<f64>>,
    /// Bandwidth-demand timelines; empty when the ctx scores the
    /// occupancy axis only ([`PlacementObjective::InterferenceAware`]).
    mem_profiles: Vec<Vec<f64>>,
    /// Per-slot resident HBM footprint in bytes; empty when capacity is
    /// not enforced.
    footprints: Vec<f64>,
    /// Device HBM capacity in bytes (only read when `footprints` is
    /// non-empty).
    capacity: f64,
}

/// An extra (not-yet-admitted) tenant appended to a candidate group:
/// serial-latency weight, occupancy timeline, bandwidth timeline.
type ExtraTenant<'a> = (f64, &'a [f64], &'a [f64]);

impl InterferenceCtx {
    /// Occupancy-only scoring (the `InterferenceAware` objective),
    /// priced with the set's own cost model.
    fn new(set: &TenantSet) -> Self {
        Self::new_with(set, &set.cost)
    }

    /// Occupancy-only scoring priced with an explicit (per-device) cost
    /// model — a T4's context weighs and profiles the same tenants
    /// differently than an A100's.
    fn new_with(set: &TenantSet, cost: &CostModel) -> Self {
        InterferenceCtx {
            weights: set
                .tenants
                .iter()
                .map(|d| cost.sequential_latency_us(d))
                .collect(),
            profiles: set.tenants.iter().map(|d| cost.occupancy_profile(d)).collect(),
            mem_profiles: Vec::new(),
            footprints: Vec::new(),
            capacity: f64::INFINITY,
        }
    }

    /// Two-dimensional roofline scoring with HBM capacity enforcement
    /// (the `MemoryAware` objective), priced with the set's cost model.
    fn roofline(set: &TenantSet) -> Self {
        Self::roofline_with(set, &set.cost)
    }

    /// Roofline scoring priced with an explicit (per-device) cost model;
    /// the HBM capacity is that model's platform capacity.
    fn roofline_with(set: &TenantSet, cost: &CostModel) -> Self {
        let mut ctx = Self::new_with(set, cost);
        ctx.mem_profiles =
            set.tenants.iter().map(|d| cost.bandwidth_profile(d)).collect();
        ctx.footprints = (0..set.len()).map(|s| set.hbm_footprint(s, None)).collect();
        ctx.capacity = cost.platform.hbm_bytes();
        ctx
    }

    /// Interference score of one co-located slot group — summed serial
    /// latency × predicted slowdown, the per-device quantity
    /// [`Placement::interference_aware`] / [`Placement::memory_aware`]
    /// minimize the maximum of — optionally with one extra
    /// (not-yet-admitted) tenant appended.
    fn score_with(&self, slots: &[usize], extra: Option<ExtraTenant<'_>>) -> f64 {
        let mut load: f64 = slots.iter().map(|&s| self.weights[s]).sum();
        let mut occ: Vec<&[f64]> =
            slots.iter().map(|&s| self.profiles[s].as_slice()).collect();
        if self.mem_profiles.is_empty() {
            if let Some((w, p, _)) = extra {
                load += w;
                occ.push(p);
            }
            return load * crate::profile::slowdown_from_phases(&occ);
        }
        let mut mem: Vec<&[f64]> =
            slots.iter().map(|&s| self.mem_profiles[s].as_slice()).collect();
        if let Some((w, p, m)) = extra {
            load += w;
            occ.push(p);
            mem.push(m);
        }
        load * crate::profile::roofline_slowdown(&occ, &mem)
    }

    fn score(&self, slots: &[usize]) -> f64 {
        self.score_with(slots, None)
    }

    /// Whether adding a tenant with footprint `extra_bytes` to `slots`
    /// stays within the device HBM capacity. Always true when the ctx
    /// does not enforce capacity.
    fn fits(&self, slots: &[usize], extra_bytes: f64) -> bool {
        if self.footprints.is_empty() {
            return true;
        }
        let used: f64 = slots.iter().map(|&s| self.footprints[s]).sum();
        used + extra_bytes <= self.capacity
    }

    /// `slot`'s resident footprint, `0.0` when capacity is not enforced.
    fn slot_footprint(&self, slot: usize) -> f64 {
        self.footprints.get(slot).copied().unwrap_or(0.0)
    }

    /// Multiply each slot's serial-latency weight by a calibrated
    /// correction factor (`scale[slot]`, one per standing tenant). Only
    /// the load axis is scaled: occupancy/bandwidth timelines stay
    /// analytic — the calibrator corrects *how much time* a tenant
    /// costs, not *which resources* it touches. HBM footprints are
    /// physical and likewise unscaled.
    fn apply_scale(&mut self, scale: &[f64]) {
        for (w, &k) in self.weights.iter_mut().zip(scale) {
            *w *= k;
        }
    }
}

/// Whether a calibration scale vector is the identity — every factor
/// exactly `1.0`. The scaled placement entry points delegate to their
/// analytic siblings in this case, which is what makes the
/// zero-observation path bit-for-bit identical (not merely numerically
/// close) to the uncalibrated engine.
fn scale_is_trivial(scale: &[f64]) -> bool {
    scale.iter().all(|&k| k == 1.0)
}

/// Max local-refinement passes [`Placement::interference_aware`] runs
/// after greedy seeding (each pass moves at most one tenant off the
/// bottleneck device; the loop also stops at the first pass with no
/// strictly improving move).
const REFINE_PASSES: usize = 16;

/// Local refinement for [`Placement::interference_aware`]: repeatedly
/// move one tenant off the bottleneck (max-score) device when the move
/// strictly lowers the max per-device interference score. Scans in
/// ascending slot/device order with first-wins ties, so the result is
/// deterministic.
///
/// `ctxs` holds one scoring context per device. A homogeneous caller
/// passes the same context reference `n` times, which makes this
/// *exactly* the single-context refinement (same floats, same ties); a
/// heterogeneous caller passes per-device contexts so every candidate
/// move is scored — and capacity-checked — against the destination
/// device's own cost model.
fn refine_interference(ctxs: &[&InterferenceCtx], assignments: &mut [Vec<usize>]) {
    let n_devices = assignments.len();
    for _ in 0..REFINE_PASSES {
        let scores: Vec<f64> =
            assignments.iter().enumerate().map(|(d, a)| ctxs[d].score(a)).collect();
        let bottleneck = (0..n_devices)
            .reduce(|a, b| if scores[b] > scores[a] { b } else { a })
            .unwrap_or(0);
        let current_max = scores[bottleneck];
        if current_max <= 0.0 {
            return;
        }
        let mut best: Option<(f64, usize, usize)> = None;
        for &slot in &assignments[bottleneck] {
            let remaining: Vec<usize> = assignments[bottleneck]
                .iter()
                .copied()
                .filter(|&s| s != slot)
                .collect();
            let src_score = ctxs[bottleneck].score(&remaining);
            for to in (0..n_devices).filter(|&t| t != bottleneck) {
                let ctx = ctxs[to];
                if !ctx.fits(&assignments[to], ctx.slot_footprint(slot)) {
                    continue;
                }
                let mut dst = assignments[to].clone();
                dst.push(slot);
                let dst_score = ctx.score(&dst);
                let new_max = scores
                    .iter()
                    .enumerate()
                    .map(|(d, &s)| {
                        if d == bottleneck {
                            src_score
                        } else if d == to {
                            dst_score
                        } else {
                            s
                        }
                    })
                    .fold(0.0f64, f64::max);
                let improves = new_max < current_max * (1.0 - 1e-9);
                let beats_best = match best {
                    None => true,
                    Some((m, _, _)) => new_max < m,
                };
                if improves && beats_best {
                    best = Some((new_max, slot, to));
                }
            }
        }
        let Some((_, slot, to)) = best else { return };
        assignments[bottleneck].retain(|&s| s != slot);
        assignments[to].push(slot);
    }
}

/// Assignment of tenant slots to devices — the placement stage of a
/// multi-GPU deployment.
///
/// GACER's regulation (chunking + pointers) is formulated per-GPU; scaling
/// to a device pool therefore splits into two decisions, VELTAIR-style:
/// *placement* (which device serves which tenant — this type) and
/// *regulation* (the per-device [`DeploymentPlan`] a per-shard search
/// produces). A placement is a partition of the global tenant slots
/// `0..n_tenants`: [`Placement::validate`] rejects assignments that place a
/// slot on two devices or on none.
///
/// Slot indices are *global* (positions in the deployed [`TenantSet`]);
/// each device sees its tenants through *local* indices — the position of
/// a slot within [`Placement::tenants_on`]. Per-device lists are kept in
/// ascending global order, so local order is stable and deterministic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// Global tenant slots per device (outer index = device).
    assignments: Vec<Vec<usize>>,
}

impl Placement {
    /// Everything on one device — the degenerate placement that reproduces
    /// the single-GPU deployment exactly.
    pub fn single_device(n_tenants: usize) -> Self {
        Placement { assignments: vec![(0..n_tenants).collect()] }
    }

    /// A placement from explicit per-device slot lists (each inner list is
    /// sorted; call [`Placement::validate`] to check partition-ness).
    pub fn from_assignments(mut assignments: Vec<Vec<usize>>) -> Self {
        for a in &mut assignments {
            a.sort_unstable();
        }
        Placement { assignments }
    }

    /// Cost-model-driven bin-packing with a load-balance objective:
    /// tenants are ordered by decreasing serial latency (the cost model's
    /// `T(O^B)` summed over the DFG) and greedily assigned to the least
    /// loaded device — the classic LPT heuristic, deterministic for a
    /// given tenant set.
    ///
    /// With more devices than tenants the surplus devices stay empty; with
    /// `n_devices == 1` this degenerates to [`Placement::single_device`].
    pub fn balanced(set: &TenantSet, n_devices: usize) -> Self {
        let n_devices = n_devices.max(1);
        let weights: Vec<f64> = set
            .tenants
            .iter()
            .map(|d| set.cost.sequential_latency_us(d))
            .collect();
        let mut order: Vec<usize> = (0..set.len()).collect();
        order.sort_by(|&a, &b| {
            weights[b]
                .partial_cmp(&weights[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut assignments = vec![Vec::new(); n_devices];
        let mut loads = vec![0.0f64; n_devices];
        for slot in order {
            let device = (0..n_devices)
                .min_by(|&a, &b| {
                    loads[a]
                        .partial_cmp(&loads[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap();
            assignments[device].push(slot);
            loads[device] += weights[slot];
        }
        Self::from_assignments(assignments)
    }

    /// Build a placement under a caller-chosen [`PlacementObjective`].
    pub fn with_objective(
        set: &TenantSet,
        n_devices: usize,
        objective: PlacementObjective,
    ) -> Self {
        match objective {
            PlacementObjective::LoadBalance => Self::balanced(set, n_devices),
            PlacementObjective::InterferenceAware => Self::interference_aware(set, n_devices),
            PlacementObjective::MemoryAware => Self::memory_aware(set, n_devices),
        }
    }

    /// Interference-aware placement: minimize the max per-device
    /// `load × predicted co-location slowdown` over the **occupancy**
    /// curves only ([`CostModel::occupancy_slowdown`]).
    ///
    /// Greedy seeding in LPT order (each tenant goes where the resulting
    /// max score is smallest), then bounded local refinement (move one
    /// tenant off the bottleneck device while it strictly lowers the max
    /// score). Deterministic for a given tenant set: every scan is in
    /// ascending slot/device order and ties keep the first candidate.
    /// When no co-location overflows the pool, every slowdown is 1.0 and
    /// this reduces to load balancing.
    pub fn interference_aware(set: &TenantSet, n_devices: usize) -> Self {
        let ctx = InterferenceCtx::new(set);
        Self::min_max_greedy(set, &vec![&ctx; n_devices.max(1)])
    }

    /// Memory-aware placement: same greedy + refinement as
    /// [`Placement::interference_aware`], but scoring the full
    /// two-dimensional roofline ([`CostModel::colocation_slowdown`]:
    /// per phase, `max(SM overflow, bandwidth oversubscription)`) and
    /// preferring devices whose remaining HBM capacity fits the slot's
    /// resident footprint ([`TenantSet::hbm_footprint`]). Construction is
    /// total — if no device can fit a slot, the best-scoring device takes
    /// it anyway (hard refusal lives on the admission path,
    /// [`Placement::fit_memory_aware`], which returns
    /// [`Error::MemoryCapacity`]).
    pub fn memory_aware(set: &TenantSet, n_devices: usize) -> Self {
        let ctx = InterferenceCtx::roofline(set);
        Self::min_max_greedy(set, &vec![&ctx; n_devices.max(1)])
    }

    /// Shared greedy min-max seeding + local refinement for the two
    /// interference objectives; `ctxs` (one per device — homogeneous
    /// callers repeat one shared reference) decide the slowdown model
    /// and whether HBM capacity constrains candidate devices.
    ///
    /// Slots are seeded in decreasing weight order; a slot's ordering
    /// weight is its **max across devices** (on a uniform pool this is
    /// bit-for-bit the single-device weight, so the homogeneous path is
    /// unchanged; on a mixed pool the pessimistic size keeps LPT's
    /// big-rocks-first property however the devices price them).
    fn min_max_greedy(set: &TenantSet, ctxs: &[&InterferenceCtx]) -> Self {
        let n_devices = ctxs.len();
        let order_weight = |s: usize| {
            ctxs.iter().map(|c| c.weights[s]).fold(f64::NEG_INFINITY, f64::max)
        };
        let mut order: Vec<usize> = (0..set.len()).collect();
        order.sort_by(|&a, &b| {
            order_weight(b)
                .partial_cmp(&order_weight(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut assignments: Vec<Vec<usize>> = vec![Vec::new(); n_devices];
        let mut scores = vec![0.0f64; n_devices];
        for slot in order {
            let any_fits = assignments
                .iter()
                .enumerate()
                .any(|(d, a)| ctxs[d].fits(a, ctxs[d].slot_footprint(slot)));
            let mut best: Option<(f64, f64, usize)> = None;
            for (d, a) in assignments.iter().enumerate() {
                let ctx = ctxs[d];
                // Capacity constraint: skip devices the slot cannot fit
                // on, unless no device fits (best-effort construction).
                if any_fits && !ctx.fits(a, ctx.slot_footprint(slot)) {
                    continue;
                }
                let mut trial = a.clone();
                trial.push(slot);
                let trial_score = ctx.score(&trial);
                let resulting_max = scores
                    .iter()
                    .enumerate()
                    .map(|(o, &s)| if o == d { trial_score } else { s })
                    .fold(0.0f64, f64::max);
                let beats = |m: f64, s: f64| {
                    resulting_max < m || (resulting_max == m && trial_score < s)
                };
                let better = match best {
                    None => true,
                    Some((m, s, _)) => beats(m, s),
                };
                if better {
                    best = Some((resulting_max, trial_score, d));
                }
            }
            let (_, score, device) = best.expect("n_devices >= 1");
            assignments[device].push(slot);
            scores[device] = score;
        }
        refine_interference(ctxs, &mut assignments);
        Self::from_assignments(assignments)
    }

    /// Pool-aware [`Placement::balanced`]: LPT on **per-device** serial
    /// latencies. Every tenant is priced by each device's own cost model
    /// and greedily assigned to the device whose *resulting* load (its
    /// current load plus the tenant **at that device's speed**) is
    /// smallest — so an A100 absorbs proportionally more work than a T4
    /// beside it. On a uniform pool matching the set's cost model this
    /// delegates to the classic homogeneous path bit-for-bit.
    pub fn balanced_pool(set: &TenantSet, pool: &DevicePool) -> Self {
        if pool.is_uniform() && *pool.platform(0) == set.cost.platform {
            return Self::balanced(set, pool.len());
        }
        let n_devices = pool.len();
        let weights: Vec<Vec<f64>> = (0..n_devices)
            .map(|d| {
                set.tenants
                    .iter()
                    .map(|t| pool.cost(d).sequential_latency_us(t))
                    .collect()
            })
            .collect();
        let order_weight = |s: usize| {
            weights.iter().map(|w| w[s]).fold(f64::NEG_INFINITY, f64::max)
        };
        let mut order: Vec<usize> = (0..set.len()).collect();
        order.sort_by(|&a, &b| {
            order_weight(b)
                .partial_cmp(&order_weight(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut assignments = vec![Vec::new(); n_devices];
        let mut loads = vec![0.0f64; n_devices];
        for slot in order {
            let device = (0..n_devices)
                .min_by(|&a, &b| {
                    (loads[a] + weights[a][slot])
                        .partial_cmp(&(loads[b] + weights[b][slot]))
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap();
            assignments[device].push(slot);
            loads[device] += weights[device][slot];
        }
        Self::from_assignments(assignments)
    }

    /// Pool-aware [`Placement::interference_aware`]: each device scores
    /// candidate groups with its **own** occupancy curves and serial
    /// latencies, so a group that overflows a T4's 40-SM pool is priced
    /// as interfering there even though an A100 would absorb it.
    pub fn interference_aware_pool(set: &TenantSet, pool: &DevicePool) -> Self {
        if pool.is_uniform() && *pool.platform(0) == set.cost.platform {
            return Self::interference_aware(set, pool.len());
        }
        let ctxs: Vec<InterferenceCtx> =
            (0..pool.len()).map(|d| InterferenceCtx::new_with(set, pool.cost(d))).collect();
        Self::min_max_greedy(set, &ctxs.iter().collect::<Vec<_>>())
    }

    /// Pool-aware [`Placement::memory_aware`]: per-device roofline
    /// scoring **and per-device HBM capacity** — a 16 GB T4 refuses
    /// groups its own capacity cannot hold even when the pool's A100s
    /// could.
    pub fn memory_aware_pool(set: &TenantSet, pool: &DevicePool) -> Self {
        if pool.is_uniform() && *pool.platform(0) == set.cost.platform {
            return Self::memory_aware(set, pool.len());
        }
        let ctxs: Vec<InterferenceCtx> = (0..pool.len())
            .map(|d| InterferenceCtx::roofline_with(set, pool.cost(d)))
            .collect();
        Self::min_max_greedy(set, &ctxs.iter().collect::<Vec<_>>())
    }

    /// Build a pool-aware placement under a caller-chosen objective —
    /// the heterogeneous sibling of [`Placement::with_objective`].
    pub fn with_objective_pool(
        set: &TenantSet,
        pool: &DevicePool,
        objective: PlacementObjective,
    ) -> Self {
        match objective {
            PlacementObjective::LoadBalance => Self::balanced_pool(set, pool),
            PlacementObjective::InterferenceAware => {
                Self::interference_aware_pool(set, pool)
            }
            PlacementObjective::MemoryAware => Self::memory_aware_pool(set, pool),
        }
    }

    /// Number of devices (bins), including empty ones.
    pub fn n_devices(&self) -> usize {
        self.assignments.len()
    }

    /// Total tenant slots placed across all devices.
    pub fn n_tenants(&self) -> usize {
        self.assignments.iter().map(Vec::len).sum()
    }

    /// Global tenant slots on `device`, in ascending order.
    pub fn tenants_on(&self, device: usize) -> &[usize] {
        self.assignments.get(device).map_or(&[], |a| a.as_slice())
    }

    /// Locate a global slot: `(device, local index)`.
    pub fn locate(&self, slot: usize) -> Option<(usize, usize)> {
        self.assignments
            .iter()
            .enumerate()
            .find_map(|(d, a)| a.iter().position(|&s| s == slot).map(|l| (d, l)))
    }

    /// The device a global slot is placed on.
    pub fn device_of(&self, slot: usize) -> Option<usize> {
        self.locate(slot).map(|(d, _)| d)
    }

    /// Place a (newly admitted) global slot on `device`, keeping the
    /// device's list sorted.
    pub fn assign(&mut self, slot: usize, device: usize) {
        let a = &mut self.assignments[device];
        let at = a.partition_point(|&s| s < slot);
        a.insert(at, slot);
    }

    /// Re-home a placed slot onto `device` without compacting slot
    /// indices (tenant **migration**: the tenant keeps its global slot,
    /// only its device changes). Returns the device the slot came from,
    /// `None` if the slot is unplaced. Moving a slot onto its own device
    /// is a no-op.
    pub fn move_slot(&mut self, slot: usize, device: usize) -> Option<usize> {
        let (from, local) = self.locate(slot)?;
        if from != device {
            self.assignments[from].remove(local);
            self.assign(slot, device);
        }
        Some(from)
    }

    /// Scale-out: append an empty device bin (the new device starts with
    /// no tenants; a replan or migrations populate it).
    pub fn push_device(&mut self) {
        self.assignments.push(Vec::new());
    }

    /// Scale-in: drop the device at dense index `device`, returning the
    /// global slots that were still placed on it (empty after a drain).
    /// Later devices shift down by one — exactly mirroring
    /// [`crate::profile::DevicePool::remove`]'s dense-index compaction.
    pub fn remove_device(&mut self, device: usize) -> Vec<usize> {
        self.assignments.remove(device)
    }

    /// Remove a global slot (eviction) and shift the later slots down —
    /// mirroring [`TenantSet::evict`]'s index compaction. Returns the
    /// device the slot was placed on.
    pub fn remove_slot(&mut self, slot: usize) -> Option<usize> {
        let (device, local) = self.locate(slot)?;
        self.assignments[device].remove(local);
        for a in &mut self.assignments {
            for s in a.iter_mut() {
                if *s > slot {
                    *s -= 1;
                }
            }
        }
        Some(device)
    }

    /// Per-device load under the cost model: summed serial latency of the
    /// placed tenants (the bin-packing objective's bin heights).
    pub fn loads(&self, set: &TenantSet) -> Vec<f64> {
        self.assignments
            .iter()
            .map(|a| {
                a.iter()
                    .map(|&s| set.cost.sequential_latency_us(&set.tenants[s]))
                    .sum()
            })
            .collect()
    }

    /// The least loaded device under the cost model — where cross-device
    /// admission control places a newcomer (ties break toward the lowest
    /// device index).
    pub fn least_loaded(&self, set: &TenantSet) -> usize {
        let loads = self.loads(set);
        (0..self.n_devices())
            .min_by(|&a, &b| {
                loads[a]
                    .partial_cmp(&loads[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap_or(0)
    }

    /// Per-device predicted co-location slowdown under the cost model's
    /// two-dimensional roofline ([`CostModel::colocation_slowdown`]:
    /// per phase, the max of SM-pool overflow and memory-bandwidth
    /// oversubscription); `1.0` means the device's tenants saturate
    /// neither dimension together (empty and single-tenant devices are
    /// always `1.0`).
    pub fn predicted_slowdowns(&self, set: &TenantSet) -> Vec<f64> {
        self.assignments
            .iter()
            .map(|a| {
                let dfgs: Vec<&Dfg> = a.iter().map(|&s| &set.tenants[s]).collect();
                set.cost.colocation_slowdown(&dfgs)
            })
            .collect()
    }

    /// The occupancy-only sibling of [`Placement::predicted_slowdowns`]
    /// ([`CostModel::occupancy_slowdown`]) — what the
    /// `InterferenceAware` objective sees, kept as the comparison arm of
    /// the `gacer-bench memory` experiment.
    pub fn predicted_occupancy_slowdowns(&self, set: &TenantSet) -> Vec<f64> {
        self.assignments
            .iter()
            .map(|a| {
                let dfgs: Vec<&Dfg> = a.iter().map(|&s| &set.tenants[s]).collect();
                set.cost.occupancy_slowdown(&dfgs)
            })
            .collect()
    }

    /// Per-device interference score: `load × predicted occupancy-only
    /// slowdown` — the quantity [`Placement::interference_aware`]
    /// minimizes the maximum of, and what interference-aware
    /// admission/migration compare.
    pub fn interference_scores(&self, set: &TenantSet) -> Vec<f64> {
        let ctx = InterferenceCtx::new(set);
        self.assignments.iter().map(|a| ctx.score(a)).collect()
    }

    /// Per-device memory-aware score: `load × predicted roofline
    /// slowdown` — the quantity [`Placement::memory_aware`] minimizes
    /// the maximum of, and what memory-aware admission/migration compare.
    pub fn memory_scores(&self, set: &TenantSet) -> Vec<f64> {
        let ctx = InterferenceCtx::roofline(set);
        self.assignments.iter().map(|a| ctx.score(a)).collect()
    }

    /// Per-device resident HBM usage in bytes: the summed unregulated
    /// footprints ([`TenantSet::hbm_footprint`]) of the placed tenants.
    pub fn hbm_usage(&self, set: &TenantSet) -> Vec<f64> {
        self.assignments
            .iter()
            .map(|a| a.iter().map(|&s| set.hbm_footprint(s, None)).sum())
            .collect()
    }

    /// The interference-scored sibling of [`Placement::least_loaded`]:
    /// the device where admitting `newcomer` least raises the cluster's
    /// max per-device interference score (ties break toward the smaller
    /// resulting device score, then the lowest device index). This is
    /// what cross-device admission control uses when the deployment's
    /// objective is [`PlacementObjective::InterferenceAware`] — a
    /// pool-saturating newcomer avoids devices already holding a
    /// saturating tenant even when they are the least loaded.
    pub fn least_interfering(&self, set: &TenantSet, newcomer: &Dfg) -> usize {
        let ctx = InterferenceCtx::new(set);
        let extra_weight = set.cost.sequential_latency_us(newcomer);
        let extra_profile = set.cost.occupancy_profile(newcomer);
        let scores: Vec<f64> = self.assignments.iter().map(|a| ctx.score(a)).collect();
        let mut best = 0usize;
        let mut best_key = (f64::INFINITY, f64::INFINITY);
        for (d, a) in self.assignments.iter().enumerate() {
            let trial =
                ctx.score_with(a, Some((extra_weight, extra_profile.as_slice(), &[])));
            let resulting_max = scores
                .iter()
                .enumerate()
                .map(|(o, &s)| if o == d { trial } else { s })
                .fold(0.0f64, f64::max);
            if resulting_max < best_key.0
                || (resulting_max == best_key.0 && trial < best_key.1)
            {
                best = d;
                best_key = (resulting_max, trial);
            }
        }
        best
    }

    /// The memory-aware admission chooser: the device where admitting
    /// `newcomer` least raises the cluster's max per-device roofline
    /// score, **restricted to devices whose remaining HBM capacity fits
    /// the newcomer's resident footprint**. When no device fits — the
    /// tenant would fit by compute but not by memory — returns the typed
    /// [`Error::MemoryCapacity`] instead of placing it anyway (ties break
    /// toward the smaller resulting device score, then the lowest device
    /// index).
    pub fn fit_memory_aware(&self, set: &TenantSet, newcomer: &Dfg) -> Result<usize> {
        let ctx = InterferenceCtx::roofline(set);
        let footprint = TenantSet::dfg_footprint(newcomer, None);
        let usage = self.hbm_usage(set);
        let capacity = set.cost.platform.hbm_bytes();
        if !usage.iter().any(|&u| u + footprint <= capacity) {
            let gb = 1e-9;
            let min_used = usage.iter().copied().fold(f64::INFINITY, f64::min);
            return Err(Error::MemoryCapacity(format!(
                "tenant {}: footprint {:.2} GB exceeds the {:.2} GB free on the \
                 emptiest of {} device(s) ({:.2} GB HBM each)",
                newcomer.name,
                footprint * gb,
                (capacity - min_used).max(0.0) * gb,
                self.n_devices(),
                capacity * gb,
            )));
        }
        let extra_weight = set.cost.sequential_latency_us(newcomer);
        let extra_occ = set.cost.occupancy_profile(newcomer);
        let extra_mem = set.cost.bandwidth_profile(newcomer);
        let scores: Vec<f64> = self.assignments.iter().map(|a| ctx.score(a)).collect();
        let mut best: Option<(usize, f64, f64)> = None;
        for (d, a) in self.assignments.iter().enumerate() {
            if usage[d] + footprint > capacity {
                continue;
            }
            let trial = ctx.score_with(
                a,
                Some((extra_weight, extra_occ.as_slice(), extra_mem.as_slice())),
            );
            let resulting_max = scores
                .iter()
                .enumerate()
                .map(|(o, &s)| if o == d { trial } else { s })
                .fold(0.0f64, f64::max);
            let better = match best {
                None => true,
                Some((_, m, s)) => {
                    resulting_max < m || (resulting_max == m && trial < s)
                }
            };
            if better {
                best = Some((d, resulting_max, trial));
            }
        }
        Ok(best.expect("at least one device fits").0)
    }

    /// Pool-aware [`Placement::loads`]: each device's load is the summed
    /// serial latency of its tenants **at that device's speed** (its own
    /// cost model), so the same tenant contributes more load on a T4
    /// than on an A100. These are device-local microseconds — already
    /// normalized by device throughput, directly comparable across a
    /// mixed pool.
    pub fn loads_pool(&self, set: &TenantSet, pool: &DevicePool) -> Vec<f64> {
        self.assignments
            .iter()
            .enumerate()
            .map(|(d, a)| {
                a.iter()
                    .map(|&s| pool.cost(d).sequential_latency_us(&set.tenants[s]))
                    .sum()
            })
            .collect()
    }

    /// Pool-aware [`Placement::least_loaded`]: the device where admitting
    /// `newcomer` leaves the smallest resulting load, with both the
    /// standing load and the newcomer priced by each device's own cost
    /// model (ties break toward the lowest device index). On a uniform
    /// pool the newcomer's weight is identical everywhere, so this picks
    /// the same device as the homogeneous chooser.
    pub fn least_loaded_pool(
        &self,
        set: &TenantSet,
        pool: &DevicePool,
        newcomer: &Dfg,
    ) -> usize {
        if pool.is_uniform() && *pool.platform(0) == set.cost.platform {
            return self.least_loaded(set);
        }
        let loads = self.loads_pool(set, pool);
        (0..self.n_devices())
            .min_by(|&a, &b| {
                (loads[a] + pool.cost(a).sequential_latency_us(newcomer))
                    .partial_cmp(&(loads[b] + pool.cost(b).sequential_latency_us(newcomer)))
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap_or(0)
    }

    /// Pool-aware [`Placement::least_interfering`]: the newcomer's
    /// weight and occupancy timeline are re-priced per candidate device,
    /// and every device's standing score uses its own context.
    pub fn least_interfering_pool(
        &self,
        set: &TenantSet,
        pool: &DevicePool,
        newcomer: &Dfg,
    ) -> usize {
        if pool.is_uniform() && *pool.platform(0) == set.cost.platform {
            return self.least_interfering(set, newcomer);
        }
        let ctxs: Vec<InterferenceCtx> =
            (0..pool.len()).map(|d| InterferenceCtx::new_with(set, pool.cost(d))).collect();
        let scores: Vec<f64> =
            self.assignments.iter().enumerate().map(|(d, a)| ctxs[d].score(a)).collect();
        let mut best = 0usize;
        let mut best_key = (f64::INFINITY, f64::INFINITY);
        for (d, a) in self.assignments.iter().enumerate() {
            let extra_weight = pool.cost(d).sequential_latency_us(newcomer);
            let extra_profile = pool.cost(d).occupancy_profile(newcomer);
            let trial =
                ctxs[d].score_with(a, Some((extra_weight, extra_profile.as_slice(), &[])));
            let resulting_max = scores
                .iter()
                .enumerate()
                .map(|(o, &s)| if o == d { trial } else { s })
                .fold(0.0f64, f64::max);
            if resulting_max < best_key.0
                || (resulting_max == best_key.0 && trial < best_key.1)
            {
                best = d;
                best_key = (resulting_max, trial);
            }
        }
        best
    }

    /// Pool-aware [`Placement::fit_memory_aware`]: candidate devices are
    /// restricted by **their own** HBM capacity (a 16 GB T4 beside a
    /// 40 GB A100 refuses what the A100 accepts), and scoring re-prices
    /// the newcomer per device. Returns [`Error::MemoryCapacity`] naming
    /// the roomiest device's free bytes when no device fits.
    pub fn fit_memory_aware_pool(
        &self,
        set: &TenantSet,
        pool: &DevicePool,
        newcomer: &Dfg,
    ) -> Result<usize> {
        if pool.is_uniform() && *pool.platform(0) == set.cost.platform {
            return self.fit_memory_aware(set, newcomer);
        }
        let footprint = TenantSet::dfg_footprint(newcomer, None);
        let usage = self.hbm_usage(set);
        let fits = |d: usize| usage[d] + footprint <= pool.platform(d).hbm_bytes();
        if !(0..self.n_devices()).any(|d| fits(d)) {
            let gb = 1e-9;
            let max_free = (0..self.n_devices())
                .map(|d| (pool.platform(d).hbm_bytes() - usage[d]).max(0.0))
                .fold(0.0f64, f64::max);
            return Err(Error::MemoryCapacity(format!(
                "tenant {}: footprint {:.2} GB exceeds the {:.2} GB free on the \
                 roomiest of {} device(s) ({})",
                newcomer.name,
                footprint * gb,
                max_free * gb,
                self.n_devices(),
                pool.label(),
            )));
        }
        let ctxs: Vec<InterferenceCtx> = (0..pool.len())
            .map(|d| InterferenceCtx::roofline_with(set, pool.cost(d)))
            .collect();
        let scores: Vec<f64> =
            self.assignments.iter().enumerate().map(|(d, a)| ctxs[d].score(a)).collect();
        let mut best: Option<(usize, f64, f64)> = None;
        for (d, a) in self.assignments.iter().enumerate() {
            if !fits(d) {
                continue;
            }
            let extra_weight = pool.cost(d).sequential_latency_us(newcomer);
            let extra_occ = pool.cost(d).occupancy_profile(newcomer);
            let extra_mem = pool.cost(d).bandwidth_profile(newcomer);
            let trial = ctxs[d].score_with(
                a,
                Some((extra_weight, extra_occ.as_slice(), extra_mem.as_slice())),
            );
            let resulting_max = scores
                .iter()
                .enumerate()
                .map(|(o, &s)| if o == d { trial } else { s })
                .fold(0.0f64, f64::max);
            let better = match best {
                None => true,
                Some((_, m, s)) => {
                    resulting_max < m || (resulting_max == m && trial < s)
                }
            };
            if better {
                best = Some((d, resulting_max, trial));
            }
        }
        Ok(best.expect("at least one device fits").0)
    }

    /// Calibration-scaled [`Placement::with_objective_pool`]: each
    /// standing slot's serial-latency weight is multiplied by
    /// `scale[slot]` — the [`crate::calibrate::Calibrator`]'s clamped
    /// `observed / predicted` correction — before the objective runs, so
    /// a tenant the analytic model underprices is packed as the heavy
    /// tenant it really is. Occupancy/bandwidth timelines and HBM
    /// footprints stay analytic (see the scaling note on the ctx).
    ///
    /// With an identity scale (every factor exactly `1.0`) this
    /// **delegates** to [`Placement::with_objective_pool`] — bit-for-bit,
    /// not approximately — which is the calibration trust-ramp contract:
    /// zero trusted observations means the analytic placement, unchanged.
    pub fn with_objective_pool_scaled(
        set: &TenantSet,
        pool: &DevicePool,
        objective: PlacementObjective,
        scale: &[f64],
    ) -> Self {
        if scale_is_trivial(scale) {
            return Self::with_objective_pool(set, pool, objective);
        }
        match objective {
            PlacementObjective::LoadBalance => {
                Self::balanced_pool_scaled(set, pool, scale)
            }
            PlacementObjective::InterferenceAware => {
                let ctxs: Vec<InterferenceCtx> = (0..pool.len())
                    .map(|d| {
                        let mut c = InterferenceCtx::new_with(set, pool.cost(d));
                        c.apply_scale(scale);
                        c
                    })
                    .collect();
                Self::min_max_greedy(set, &ctxs.iter().collect::<Vec<_>>())
            }
            PlacementObjective::MemoryAware => {
                let ctxs: Vec<InterferenceCtx> = (0..pool.len())
                    .map(|d| {
                        let mut c = InterferenceCtx::roofline_with(set, pool.cost(d));
                        c.apply_scale(scale);
                        c
                    })
                    .collect();
                Self::min_max_greedy(set, &ctxs.iter().collect::<Vec<_>>())
            }
        }
    }

    /// Calibration-scaled [`Placement::balanced_pool`]: LPT over
    /// per-device serial latencies multiplied by each slot's correction
    /// factor. Identity scale delegates to the analytic sibling.
    pub fn balanced_pool_scaled(
        set: &TenantSet,
        pool: &DevicePool,
        scale: &[f64],
    ) -> Self {
        if scale_is_trivial(scale) {
            return Self::balanced_pool(set, pool);
        }
        let n_devices = pool.len();
        let weights: Vec<Vec<f64>> = (0..n_devices)
            .map(|d| {
                set.tenants
                    .iter()
                    .enumerate()
                    .map(|(s, t)| {
                        pool.cost(d).sequential_latency_us(t)
                            * scale.get(s).copied().unwrap_or(1.0)
                    })
                    .collect()
            })
            .collect();
        let order_weight = |s: usize| {
            weights.iter().map(|w| w[s]).fold(f64::NEG_INFINITY, f64::max)
        };
        let mut order: Vec<usize> = (0..set.len()).collect();
        order.sort_by(|&a, &b| {
            order_weight(b)
                .partial_cmp(&order_weight(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut assignments = vec![Vec::new(); n_devices];
        let mut loads = vec![0.0f64; n_devices];
        for slot in order {
            let device = (0..n_devices)
                .min_by(|&a, &b| {
                    (loads[a] + weights[a][slot])
                        .partial_cmp(&(loads[b] + weights[b][slot]))
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap();
            assignments[device].push(slot);
            loads[device] += weights[device][slot];
        }
        Self::from_assignments(assignments)
    }

    /// Calibration-scaled [`Placement::least_loaded_pool`]: standing
    /// loads are corrected by `scale`; the newcomer has no residual yet
    /// (trust ramp) so it is priced analytically everywhere. Identity
    /// scale delegates to the analytic sibling.
    pub fn least_loaded_pool_scaled(
        &self,
        set: &TenantSet,
        pool: &DevicePool,
        newcomer: &Dfg,
        scale: &[f64],
    ) -> usize {
        if scale_is_trivial(scale) {
            return self.least_loaded_pool(set, pool, newcomer);
        }
        let loads: Vec<f64> = self
            .assignments
            .iter()
            .enumerate()
            .map(|(d, a)| {
                a.iter()
                    .map(|&s| {
                        pool.cost(d).sequential_latency_us(&set.tenants[s])
                            * scale.get(s).copied().unwrap_or(1.0)
                    })
                    .sum()
            })
            .collect();
        (0..self.n_devices())
            .min_by(|&a, &b| {
                (loads[a] + pool.cost(a).sequential_latency_us(newcomer))
                    .partial_cmp(
                        &(loads[b] + pool.cost(b).sequential_latency_us(newcomer)),
                    )
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap_or(0)
    }

    /// Calibration-scaled [`Placement::least_interfering_pool`]: every
    /// device's standing score uses calibrated weights; the newcomer is
    /// analytic (no residual yet). Identity scale delegates.
    pub fn least_interfering_pool_scaled(
        &self,
        set: &TenantSet,
        pool: &DevicePool,
        newcomer: &Dfg,
        scale: &[f64],
    ) -> usize {
        if scale_is_trivial(scale) {
            return self.least_interfering_pool(set, pool, newcomer);
        }
        let ctxs: Vec<InterferenceCtx> = (0..pool.len())
            .map(|d| {
                let mut c = InterferenceCtx::new_with(set, pool.cost(d));
                c.apply_scale(scale);
                c
            })
            .collect();
        let scores: Vec<f64> =
            self.assignments.iter().enumerate().map(|(d, a)| ctxs[d].score(a)).collect();
        let mut best = 0usize;
        let mut best_key = (f64::INFINITY, f64::INFINITY);
        for (d, a) in self.assignments.iter().enumerate() {
            let extra_weight = pool.cost(d).sequential_latency_us(newcomer);
            let extra_profile = pool.cost(d).occupancy_profile(newcomer);
            let trial = ctxs[d]
                .score_with(a, Some((extra_weight, extra_profile.as_slice(), &[])));
            let resulting_max = scores
                .iter()
                .enumerate()
                .map(|(o, &s)| if o == d { trial } else { s })
                .fold(0.0f64, f64::max);
            if resulting_max < best_key.0
                || (resulting_max == best_key.0 && trial < best_key.1)
            {
                best = d;
                best_key = (resulting_max, trial);
            }
        }
        best
    }

    /// Calibration-scaled [`Placement::fit_memory_aware_pool`]: roofline
    /// scores use calibrated weights; HBM capacity checks are untouched
    /// (footprints are physical bytes — a latency correction does not
    /// change what fits). Identity scale delegates.
    pub fn fit_memory_aware_pool_scaled(
        &self,
        set: &TenantSet,
        pool: &DevicePool,
        newcomer: &Dfg,
        scale: &[f64],
    ) -> Result<usize> {
        if scale_is_trivial(scale) {
            return self.fit_memory_aware_pool(set, pool, newcomer);
        }
        let footprint = TenantSet::dfg_footprint(newcomer, None);
        let usage = self.hbm_usage(set);
        let fits = |d: usize| usage[d] + footprint <= pool.platform(d).hbm_bytes();
        if !(0..self.n_devices()).any(fits) {
            let gb = 1e-9;
            let max_free = (0..self.n_devices())
                .map(|d| (pool.platform(d).hbm_bytes() - usage[d]).max(0.0))
                .fold(0.0f64, f64::max);
            return Err(Error::MemoryCapacity(format!(
                "tenant {}: footprint {:.2} GB exceeds the {:.2} GB free on the \
                 roomiest of {} device(s) ({})",
                newcomer.name,
                footprint * gb,
                max_free * gb,
                self.n_devices(),
                pool.label(),
            )));
        }
        let ctxs: Vec<InterferenceCtx> = (0..pool.len())
            .map(|d| {
                let mut c = InterferenceCtx::roofline_with(set, pool.cost(d));
                c.apply_scale(scale);
                c
            })
            .collect();
        let scores: Vec<f64> =
            self.assignments.iter().enumerate().map(|(d, a)| ctxs[d].score(a)).collect();
        let mut best: Option<(usize, f64, f64)> = None;
        for (d, a) in self.assignments.iter().enumerate() {
            if !fits(d) {
                continue;
            }
            let extra_weight = pool.cost(d).sequential_latency_us(newcomer);
            let extra_occ = pool.cost(d).occupancy_profile(newcomer);
            let extra_mem = pool.cost(d).bandwidth_profile(newcomer);
            let trial = ctxs[d].score_with(
                a,
                Some((extra_weight, extra_occ.as_slice(), extra_mem.as_slice())),
            );
            let resulting_max = scores
                .iter()
                .enumerate()
                .map(|(o, &s)| if o == d { trial } else { s })
                .fold(0.0f64, f64::max);
            let better = match best {
                None => true,
                Some((_, m, s)) => {
                    resulting_max < m || (resulting_max == m && trial < s)
                }
            };
            if better {
                best = Some((d, resulting_max, trial));
            }
        }
        Ok(best.expect("at least one device fits").0)
    }

    /// Pool-aware [`Placement::predicted_slowdowns`]: each device's
    /// co-location slowdown is computed with its own roofline (SM pool
    /// and bandwidth peak), so the same tenant group predicts a larger
    /// slowdown on a T4 than on an A100.
    pub fn predicted_slowdowns_pool(&self, set: &TenantSet, pool: &DevicePool) -> Vec<f64> {
        self.assignments
            .iter()
            .enumerate()
            .map(|(d, a)| {
                let dfgs: Vec<&Dfg> = a.iter().map(|&s| &set.tenants[s]).collect();
                pool.cost(d).colocation_slowdown(&dfgs)
            })
            .collect()
    }

    /// Pool-aware [`Placement::interference_scores`].
    pub fn interference_scores_pool(&self, set: &TenantSet, pool: &DevicePool) -> Vec<f64> {
        self.assignments
            .iter()
            .enumerate()
            .map(|(d, a)| InterferenceCtx::new_with(set, pool.cost(d)).score(a))
            .collect()
    }

    /// Pool-aware [`Placement::memory_scores`].
    pub fn memory_scores_pool(&self, set: &TenantSet, pool: &DevicePool) -> Vec<f64> {
        self.assignments
            .iter()
            .enumerate()
            .map(|(d, a)| InterferenceCtx::roofline_with(set, pool.cost(d)).score(a))
            .collect()
    }

    /// Project a global per-tenant sequence down to `device`'s tenants, in
    /// local order (used to build per-shard tenant sets, specs, variants).
    pub fn select<T: Clone>(&self, items: &[T], device: usize) -> Vec<T> {
        self.tenants_on(device).iter().map(|&s| items[s].clone()).collect()
    }

    /// Check the placement is a partition of `0..n_tenants`: every slot
    /// appears on exactly one device and no slot is out of range.
    pub fn validate(&self, n_tenants: usize) -> Result<()> {
        if self.assignments.is_empty() {
            return Err(Error::InvalidPlan("placement has zero devices".into()));
        }
        let mut owner: Vec<Option<usize>> = vec![None; n_tenants];
        for (d, a) in self.assignments.iter().enumerate() {
            for &s in a {
                if s >= n_tenants {
                    return Err(Error::InvalidPlan(format!(
                        "placement puts slot {s} on device {d}, only {n_tenants} tenants"
                    )));
                }
                if let Some(prev) = owner[s].replace(d) {
                    return Err(Error::InvalidPlan(format!(
                        "placement puts slot {s} on devices {prev} and {d}"
                    )));
                }
            }
        }
        if let Some(s) = owner.iter().position(Option::is_none) {
            return Err(Error::InvalidPlan(format!(
                "placement leaves slot {s} unassigned"
            )));
        }
        Ok(())
    }
}

/// A multi-device deployment configuration: the [`Placement`] plus one
/// independently searched [`DeploymentPlan`] per device.
///
/// Each shard plan is expressed in the device's *local* tenant indices
/// (position within [`Placement::tenants_on`]); [`Self::merged`] projects
/// the shards back onto global slot order, which is what keeps the
/// single-device plan APIs working unchanged on a sharded engine.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedDeploymentPlan {
    /// Which device serves which tenant slot.
    pub placement: Placement,
    /// One regulation plan per device, in the device's local tenant order.
    pub shards: Vec<DeploymentPlan>,
}

impl ShardedDeploymentPlan {
    /// The unregulated sharded plan for a placement: every shard starts at
    /// Stream-Parallel (no chunking, no pointers).
    pub fn unregulated(placement: Placement) -> Self {
        let shards = (0..placement.n_devices())
            .map(|d| DeploymentPlan::unregulated(placement.tenants_on(d).len()))
            .collect();
        ShardedDeploymentPlan { placement, shards }
    }

    /// Number of devices (== shard count).
    pub fn n_devices(&self) -> usize {
        self.shards.len()
    }

    /// Validate the device dimension and every shard:
    ///
    /// * the placement must partition `0..tenants.len()` (overlapping or
    ///   missing tenant assignments are rejected);
    /// * there must be exactly one shard plan per device;
    /// * each shard plan must validate against its device's tenants
    ///   (chunk sums, pointer ranges — [`DeploymentPlan::validate`]).
    pub fn validate(&self, tenants: &[Dfg]) -> Result<()> {
        self.placement.validate(tenants.len())?;
        if self.shards.len() != self.placement.n_devices() {
            return Err(Error::InvalidPlan(format!(
                "{} shard plans for {} devices",
                self.shards.len(),
                self.placement.n_devices()
            )));
        }
        for (d, shard) in self.shards.iter().enumerate() {
            let local = self.placement.select(tenants, d);
            shard.validate(&local).map_err(|e| {
                Error::InvalidPlan(format!("device {d}: {e}"))
            })?;
        }
        Ok(())
    }

    /// Device-level plan diff: the devices whose deployment changed
    /// between `old` and `self` — a different tenant slot membership
    /// (placement) or a different shard plan.
    ///
    /// The comparison is by **global slot number**. Admission appends a
    /// slot and migration preserves them, so for those events exactly
    /// the re-searched devices diff; an *eviction* compacts every later
    /// slot down, which renumbers other devices' membership lists too —
    /// they then diff as changed even though their tenants and shard
    /// plans are identical. The serving-path diff is immune to this:
    /// [`crate::coordinator::ClusterServer::apply`] compares lowered
    /// deployments (tenant specs, no slot numbers), so an eviction still
    /// hot-swaps only the device that lost the tenant.
    ///
    /// ```
    /// use gacer::plan::{Placement, ShardedDeploymentPlan};
    ///
    /// let p = Placement::from_assignments(vec![vec![0], vec![1], vec![2]]);
    /// let old = ShardedDeploymentPlan::unregulated(p);
    /// let mut new = old.clone();
    /// new.shards[2].pointers.set_list(0, vec![3]);
    /// assert_eq!(new.changed_devices(&old), vec![2]);
    /// // Migrating slot 0 onto device 1 changes devices 0 and 1 only.
    /// let mut moved = old.clone();
    /// moved.placement.move_slot(0, 1);
    /// moved.shards[0] = gacer::plan::DeploymentPlan::unregulated(0);
    /// moved.shards[1] = gacer::plan::DeploymentPlan::unregulated(2);
    /// assert_eq!(moved.changed_devices(&old), vec![0, 1]);
    /// ```
    pub fn changed_devices(&self, old: &ShardedDeploymentPlan) -> Vec<usize> {
        let n = self.n_devices().max(old.n_devices());
        (0..n)
            .filter(|&d| {
                self.placement.tenants_on(d) != old.placement.tenants_on(d)
                    || self.shards.get(d) != old.shards.get(d)
            })
            .collect()
    }

    /// Project the shards back onto global slot order: one chunk map and
    /// pointer list per global tenant, pulled from the tenant's shard.
    ///
    /// The merged plan drops the device dimension (it says nothing about
    /// which tenants contend), but it is exactly the right shape for
    /// per-tenant introspection and for validating against the full
    /// tenant set. Fails when the placement does not cover every slot.
    pub fn merged(&self) -> Result<DeploymentPlan> {
        let n = self.placement.n_tenants();
        let mut chunking = Vec::with_capacity(n);
        let mut lists = Vec::with_capacity(n);
        for slot in 0..n {
            let (d, l) = self.placement.locate(slot).ok_or_else(|| {
                Error::InvalidPlan(format!("placement leaves slot {slot} unassigned"))
            })?;
            let shard = self.shards.get(d).ok_or_else(|| {
                Error::InvalidPlan(format!("no shard plan for device {d}"))
            })?;
            chunking.push(shard.chunking.get(l).cloned().unwrap_or_default());
            lists.push(shard.pointers.list(l).to_vec());
        }
        Ok(DeploymentPlan {
            chunking,
            pointers: PointerMatrix::from_lists(lists),
        })
    }
}

/// A set of tenant DFGs deployed together, with the cost model that prices
/// their operators.
///
/// The set **owns** its DFGs: the engine admits and evicts tenants at
/// runtime, so the deployed population cannot be a borrow of some longer-
/// lived slice. (Cloning a DFG is cheap — a name plus a flat operator
/// list.)
pub struct TenantSet {
    pub tenants: Vec<Dfg>,
    pub cost: CostModel,
}

impl TenantSet {
    pub fn new(tenants: Vec<Dfg>, cost: CostModel) -> Self {
        TenantSet { tenants, cost }
    }

    /// Number of deployed tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Add a tenant; returns its slot index.
    pub fn admit(&mut self, dfg: Dfg) -> usize {
        self.tenants.push(dfg);
        self.tenants.len() - 1
    }

    /// Remove the tenant at `index` (later slots shift down).
    pub fn evict(&mut self, index: usize) -> Dfg {
        self.tenants.remove(index)
    }

    /// The sub-set of tenants placed on `device` (cloned DFGs + the shared
    /// cost model) — the per-device search input of a sharded deployment.
    pub fn shard(&self, placement: &Placement, device: usize) -> TenantSet {
        TenantSet::new(placement.select(&self.tenants, device), self.cost.clone())
    }

    /// [`TenantSet::shard`] priced with an explicit per-device cost
    /// model — the heterogeneous search input: the shard's simulation,
    /// HBM pressure, and operator costs all use `cost`'s platform
    /// (its roofline, its capacity), not the set-wide one.
    pub fn shard_on(&self, placement: &Placement, device: usize, cost: &CostModel) -> TenantSet {
        TenantSet::new(placement.select(&self.tenants, device), cost.clone())
    }

    /// Resident HBM footprint of `dfg` in bytes under an optional chunk
    /// map: every operator's weights stay resident for the tenant's
    /// lifetime ([`OpKind::weight_bytes`]), plus the peak activation
    /// working set across operators at each operator's *effective* batch
    /// — the largest `list_B` piece when the op is decomposed
    /// ([`OpKind::activation_bytes`]), so chunking shrinks the resident
    /// variant a memory-bound tenant must hold.
    pub fn dfg_footprint(dfg: &Dfg, chunks: Option<&ChunkMap>) -> f64 {
        let mut weights = 0.0;
        let mut peak_act = 0.0f64;
        for op in &dfg.ops {
            weights += op.kind.weight_bytes();
            let eff = chunks
                .and_then(|m| m.get(&op.id))
                .and_then(|l| l.iter().copied().max())
                .unwrap_or(op.batch);
            peak_act = peak_act.max(op.kind.activation_bytes(eff));
        }
        weights + peak_act
    }

    /// [`TenantSet::dfg_footprint`] of the deployed tenant at `slot`.
    pub fn hbm_footprint(&self, slot: usize, chunks: Option<&ChunkMap>) -> f64 {
        Self::dfg_footprint(&self.tenants[slot], chunks)
    }

    /// Total resident footprint of the set under a plan's chunking.
    pub fn hbm_footprint_total(&self, plan: &DeploymentPlan) -> f64 {
        (0..self.len())
            .map(|t| self.hbm_footprint(t, plan.chunking.get(t)))
            .sum()
    }

    /// Soft HBM-oversubscription pressure in microseconds — the
    /// footprint half of the search objective's footprint-vs-occupancy
    /// trade. Zero whenever the set's resident footprint under `plan`
    /// fits the platform's HBM (every ordinary mix); above capacity, the
    /// overflow fraction scaled by the set's summed serial latency, so
    /// a decomposition that brings the resident variants back under
    /// capacity is worth as much as removing that fraction of the
    /// makespan. Depends only on the plan's chunking — pointer moves
    /// never change it.
    pub fn hbm_pressure_us(&self, plan: &DeploymentPlan) -> f64 {
        let capacity = self.cost.platform.hbm_bytes();
        let footprint = self.hbm_footprint_total(plan);
        if footprint <= capacity {
            return 0.0;
        }
        let total_work: f64 = self
            .tenants
            .iter()
            .map(|d| self.cost.sequential_latency_us(d))
            .sum();
        (footprint / capacity - 1.0) * total_work
    }

    /// Lower tenants + plan to staged simulator streams.
    ///
    /// A decomposed operator becomes one fork-join stage whose micro-batch
    /// pieces issue concurrently (the paper deploys decomposed copies on
    /// parallel streams, Table 3). Consecutive ops decomposed with the
    /// SAME `list_B` chain: the activation stays split (`torch.chunk` is a
    /// view), so the `Chunk` overhead is paid once at the region entry and
    /// the `Concat` once at its exit — not per operator. All inserted ops
    /// inherit the source op's segment ("decomposed operators are inserted
    /// between the pointers without affecting `Matrix_P`", §4.4).
    pub fn compile(&self, plan: &DeploymentPlan) -> Vec<Vec<SimStage>> {
        (0..self.tenants.len()).map(|ti| self.compile_tenant(ti, plan)).collect()
    }

    /// Compile one tenant's stream — the per-tenant unit of
    /// [`TenantSet::compile`]. Streams are independent across tenants
    /// (each depends only on its own DFG, chunk map, and pointer list),
    /// which is what lets the search's warm-start cache
    /// ([`crate::search::SearchState`]) recompile only the tenants whose
    /// chunking actually changed.
    pub fn compile_tenant(&self, ti: usize, plan: &DeploymentPlan) -> Vec<SimStage> {
        let dfg = &self.tenants[ti];
        let empty = ChunkMap::new();
        let chunks = plan.chunking.get(ti).unwrap_or(&empty);
        let pointers = plan.pointers.list(ti);
        let mut stream: Vec<SimStage> = Vec::with_capacity(dfg.len());
        let mut open_split: Option<&Vec<usize>> = None;
        for op in &dfg.ops {
            // Segment = number of pointers at positions <= op.id.
            let segment = pointers.iter().filter(|&&p| p <= op.id).count();
            let split = chunks.get(&op.id).filter(|l| l.len() > 1);
            // Close an open split region on change/end. The concat
            // belongs to the previous op (its segment follows that
            // op's pointer count) so segment restamping from
            // `source_op` stays exact.
            if let Some(prev) = open_split {
                if split != Some(prev) {
                    let elems = dfg.ops[op.id - 1].kind.out_elems();
                    let prev_segment =
                        pointers.iter().filter(|&&p| p <= op.id - 1).count();
                    stream.push(SimStage::solo(self.sim_op(
                        &OpKind::Concat { elems },
                        dfg.ops[op.id - 1].batch,
                        prev_segment,
                        op.id - 1,
                    )));
                    open_split = None;
                }
            }
            match split {
                Some(list_b) => {
                    if open_split.is_none() {
                        let elems = op.kind.out_elems();
                        stream.push(SimStage::solo(self.sim_op(
                            &OpKind::Chunk { elems },
                            op.batch,
                            segment,
                            op.id,
                        )));
                        open_split = Some(list_b);
                    }
                    let pieces = list_b
                        .iter()
                        .map(|&b| self.sim_op(&op.kind, b, segment, op.id))
                        .collect();
                    stream.push(SimStage { pieces });
                }
                None => stream.push(SimStage::solo(self.sim_op(
                    &op.kind, op.batch, segment, op.id,
                ))),
            }
        }
        if open_split.is_some() {
            let last = dfg.ops.last().unwrap();
            let elems = last.kind.out_elems();
            let segment = pointers.iter().filter(|&&p| p <= last.id).count();
            stream.push(SimStage::solo(self.sim_op(
                &OpKind::Concat { elems },
                last.batch,
                segment,
                last.id,
            )));
        }
        stream
    }

    fn sim_op(&self, kind: &OpKind, batch: usize, segment: usize, source: OpId) -> SimOp {
        let c = self.cost.cost_of(kind, batch);
        SimOp {
            occupancy: c.sm_occupancy,
            duration_us: c.duration_us,
            mem_util: c.mem_util,
            segment,
            source_op: source,
            class: kind.class(),
        }
    }

    /// Compile with every tenant in its own single-segment stream — the
    /// raw Stream-Parallel lowering (flat: one SimOp per operator).
    pub fn compile_unregulated(&self) -> Vec<Vec<SimOp>> {
        self.compile(&DeploymentPlan::unregulated(self.tenants.len()))
            .into_iter()
            .map(|stages| stages.into_iter().flat_map(|st| st.pieces).collect())
            .collect()
    }

    /// Compile + simulate a plan under `opts` — the modeling-based
    /// evaluation every regulation step uses (no hardware profiling per
    /// candidate, §4.4 "Search Cost Analysis"). The outcome is stamped
    /// with the plan's HBM-oversubscription pressure
    /// ([`TenantSet::hbm_pressure_us`]), so the search objective trades
    /// resident footprint against occupancy when memory is tight.
    pub fn simulate(
        &self,
        plan: &DeploymentPlan,
        opts: crate::gpu::SimOptions,
    ) -> crate::gpu::SimOutcome {
        let mut out = crate::gpu::GpuSim::new(opts).run_staged(&self.compile(plan));
        out.hbm_pressure_us = self.hbm_pressure_us(plan);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::profile::Platform;

    fn setup() -> (Vec<Dfg>, CostModel) {
        let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
        (tenants, CostModel::new(Platform::titan_v()))
    }

    #[test]
    fn unregulated_compiles_one_simop_per_op() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let streams = ts.compile_unregulated();
        for (s, d) in streams.iter().zip(&tenants) {
            assert_eq!(s.len(), d.len());
            assert!(s.iter().all(|o| o.segment == 0));
        }
    }

    #[test]
    fn chunking_forks_one_stage_with_overhead() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        // Chunk V16's first conv (tenant 1, op 0) into 2 pieces.
        plan.chunking[1].insert(0, vec![4, 4]);
        plan.validate(&tenants).unwrap();
        let streams = ts.compile(&plan);
        // +1 chunk stage, +1 concat stage (pieces share one fork stage).
        assert_eq!(streams[1].len(), tenants[1].len() + 2);
        assert_eq!(streams[1][0].pieces[0].class, "chunk");
        assert_eq!(streams[1][1].pieces.len(), 2, "fork stage has 2 pieces");
        assert_eq!(streams[1][2].pieces[0].class, "concat");
    }

    #[test]
    fn adjacent_chunked_ops_chain_one_overhead_pair() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        // V16 ops 0 (conv) and 1 (relu) chunked identically: the split
        // region opens once and closes once.
        plan.chunking[1].insert(0, vec![4, 4]);
        plan.chunking[1].insert(1, vec![4, 4]);
        let streams = ts.compile(&plan);
        let classes: Vec<&str> = streams[1]
            .iter()
            .flat_map(|st| st.pieces.iter().map(|p| p.class))
            .collect();
        assert_eq!(classes.iter().filter(|c| **c == "chunk").count(), 1);
        assert_eq!(classes.iter().filter(|c| **c == "concat").count(), 1);
        assert_eq!(streams[1].len(), tenants[1].len() + 2);
    }

    #[test]
    fn chunk_pieces_have_lower_occupancy() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        plan.chunking[1].insert(2, vec![2, 2, 2, 2]);
        let full = ts.compile_unregulated()[1][2].occupancy;
        let piece = ts.compile(&plan)[1][3].pieces[0].occupancy;
        assert!(piece <= full);
    }

    #[test]
    fn pointers_assign_segments() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        plan.pointers.set_list(0, vec![5, 10]);
        let streams = ts.compile(&plan);
        assert_eq!(streams[0][0].segment(), 0);
        assert_eq!(streams[0][5].segment(), 1);
        assert_eq!(streams[0][10].segment(), 2);
        assert_eq!(streams[0].last().unwrap().segment(), 2);
    }

    #[test]
    fn validate_rejects_bad_list_b() {
        let (tenants, _) = setup();
        let mut plan = DeploymentPlan::unregulated(3);
        plan.chunking[0].insert(0, vec![3, 3]); // batch is 8
        assert!(plan.validate(&tenants).is_err());
    }

    #[test]
    fn validate_rejects_wrong_tenant_count() {
        let (tenants, _) = setup();
        let plan = DeploymentPlan::unregulated(2);
        assert!(plan.validate(&tenants).is_err());
    }

    #[test]
    fn segments_monotone_within_stream() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        plan.pointers.set_list(1, vec![3, 9, 20]);
        for s in ts.compile(&plan) {
            for pair in s.windows(2) {
                assert!(pair[1].segment() >= pair[0].segment());
            }
        }
    }

    #[test]
    fn validate_rejects_multi_entry_list_on_non_chunkable_op() {
        // D121's dense blocks contain channel concats, which are not
        // batch-chunkable. A multi-entry list_B on one must be rejected; a
        // single-entry list (mask = 0 realization) stays legal.
        let tenants = vec![zoo::build_default("D121").unwrap()];
        let op = tenants[0]
            .ops
            .iter()
            .find(|o| !o.chunkable())
            .expect("D121 has a non-chunkable op");
        let (id, batch) = (op.id, op.batch);
        let mut plan = DeploymentPlan::unregulated(1);
        plan.chunking[0].insert(id, vec![batch / 2, batch - batch / 2]);
        assert!(matches!(
            plan.validate(&tenants),
            Err(crate::error::Error::InvalidPlan(_))
        ));
        plan.chunking[0].insert(id, vec![batch]);
        plan.validate(&tenants).unwrap();
    }

    #[test]
    fn validate_rejects_out_of_range_pointer() {
        let (tenants, _) = setup();
        let mut plan = DeploymentPlan::unregulated(3);
        plan.pointers.set_list(0, vec![tenants[0].len()]); // valid: 1..len
        assert!(plan.validate(&tenants).is_err());
        plan.pointers.set_list(0, vec![tenants[0].len() - 1]);
        plan.validate(&tenants).unwrap();
    }

    #[test]
    fn validate_rejects_unknown_op_and_zero_chunk() {
        let (tenants, _) = setup();
        let mut plan = DeploymentPlan::unregulated(3);
        plan.chunking[0].insert(10_000, vec![8]);
        assert!(plan.validate(&tenants).is_err());
        let mut plan = DeploymentPlan::unregulated(3);
        plan.chunking[0].insert(0, vec![8, 0]);
        assert!(plan.validate(&tenants).is_err());
    }

    #[test]
    fn balanced_placement_partitions_and_balances() {
        let (tenants, cost) = setup();
        let set = TenantSet::new(tenants, cost);
        let p = Placement::balanced(&set, 2);
        p.validate(set.len()).unwrap();
        assert_eq!(p.n_devices(), 2);
        assert_eq!(p.n_tenants(), 3);
        // LPT with 3 tenants on 2 devices: no device is left empty.
        assert!(!p.tenants_on(0).is_empty() && !p.tenants_on(1).is_empty());
        // Load-balance objective: the bottleneck device carries at most
        // the heaviest plus the lightest tenant (LPT's shape for 3-on-2).
        let mut weights: Vec<f64> = set
            .tenants
            .iter()
            .map(|d| set.cost.sequential_latency_us(d))
            .collect();
        weights.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let bottleneck = p.loads(&set).into_iter().fold(0.0f64, f64::max);
        assert!(bottleneck <= weights[0] + weights[2] + 1e-9);
    }

    /// The mid-network conv whose occupancy curve the cost model tests
    /// plot: batch 32 saturates the pool (`W = 100`), batch 1 holds ~10%.
    fn mid_conv() -> OpKind {
        OpKind::Conv { h: 56, w: 56, cin: 256, cout: 256, k: 3, stride: 1 }
    }

    /// A net of `n` identical mid-network convs at `batch`.
    fn conv_net(name: &str, batch: usize, n: usize) -> Dfg {
        let mut d = Dfg::new(name);
        for i in 0..n {
            d.push(mid_conv(), batch, format!("conv{i}"));
        }
        d
    }

    #[test]
    fn with_objective_dispatches() {
        let (tenants, cost) = setup();
        let set = TenantSet::new(tenants, cost);
        assert_eq!(
            Placement::with_objective(&set, 2, PlacementObjective::LoadBalance),
            Placement::balanced(&set, 2)
        );
        assert_eq!(
            Placement::with_objective(&set, 2, PlacementObjective::InterferenceAware),
            Placement::interference_aware(&set, 2)
        );
    }

    #[test]
    fn interference_aware_single_device_degenerates() {
        let (tenants, cost) = setup();
        let set = TenantSet::new(tenants, cost);
        let p = Placement::interference_aware(&set, 1);
        assert_eq!(p, Placement::single_device(3));
        // And 0 devices clamps to 1, like `balanced`.
        assert_eq!(Placement::interference_aware(&set, 0), p);
    }

    #[test]
    fn interference_aware_spreads_saturating_tenants() {
        // Two pool-saturating tenants and one bandwidth-light tenant
        // whose load exceeds either: load balance pairs the two
        // saturating tenants with nobody, interference still must not
        // pair them with each other.
        let cost = CostModel::new(Platform::titan_v());
        let d_hi = cost.cost_of(&mid_conv(), 32).duration_us;
        let d_lo = cost.cost_of(&mid_conv(), 1).duration_us;
        // Weights ~ [2, 2, 3] * d_hi: LPT puts the low-occupancy tenant
        // alone and pairs the two saturating ones.
        let n_lo = ((3.0 * d_hi) / d_lo).round() as usize;
        let tenants = vec![
            conv_net("hi-a", 32, 2),
            conv_net("hi-b", 32, 2),
            conv_net("lo", 1, n_lo.max(1)),
        ];
        let set = TenantSet::new(tenants, cost);
        let lb = Placement::balanced(&set, 2);
        assert_eq!(
            lb.device_of(0),
            lb.device_of(1),
            "precondition: LPT co-locates the saturating pair"
        );
        let ia = Placement::interference_aware(&set, 2);
        ia.validate(3).unwrap();
        assert_ne!(ia.device_of(0), ia.device_of(1), "saturating pair split");
        let max = |v: Vec<f64>| v.into_iter().fold(0.0f64, f64::max);
        assert!(
            max(ia.interference_scores(&set)) < max(lb.interference_scores(&set)),
            "interference objective must beat LPT on its own score"
        );
        assert!(max(ia.predicted_slowdowns(&set)) < max(lb.predicted_slowdowns(&set)));
    }

    /// A net of `n` bandwidth-saturating BatchNorm ops at batch 8: high
    /// `mem_util` (~96 %), floor occupancy — the tenant class the memory
    /// axis exists for.
    fn bn_net(name: &str, n: usize) -> Dfg {
        let mut d = Dfg::new(name);
        for i in 0..n {
            d.push(OpKind::BatchNorm { elems: 56 * 56 * 256 }, 8, format!("bn{i}"));
        }
        d
    }

    #[test]
    fn hbm_footprint_is_weights_plus_peak_activation() {
        let mut d = Dfg::new("t");
        d.push(OpKind::Linear { fin: 100, fout: 50 }, 4, "fc0");
        d.push(OpKind::ReLU { elems: 50 }, 4, "act");
        let weights = (100.0 * 50.0) * 4.0;
        let act_fc = 4.0 * (100.0 + 50.0) * 4.0;
        let act_relu = 4.0 * (2.0 * 50.0) * 4.0;
        let expect = weights + act_fc.max(act_relu);
        assert!((TenantSet::dfg_footprint(&d, None) - expect).abs() < 1e-9);
        // Chunking the peak op to max piece 1 shrinks the activation term.
        let mut chunks = ChunkMap::new();
        chunks.insert(0, vec![1, 1, 1, 1]);
        chunks.insert(1, vec![1, 1, 1, 1]);
        let chunked = TenantSet::dfg_footprint(&d, Some(&chunks));
        assert!(chunked < TenantSet::dfg_footprint(&d, None));
        assert!(chunked >= weights);
    }

    #[test]
    fn hbm_pressure_zero_in_capacity_and_scales_past_it() {
        let cost = CostModel::new(Platform::titan_v());
        // Ordinary mixes are far under 12 GB: zero pressure.
        let set = TenantSet::new(zoo::build_combo(&["Alex", "V16", "R18"]), cost.clone());
        let plan = DeploymentPlan::unregulated(3);
        assert_eq!(set.hbm_pressure_us(&plan), 0.0);
        assert!(set.hbm_footprint_total(&plan) < cost.platform.hbm_bytes());
        // A tenant with >12 GB of weights oversubscribes: positive
        // pressure, and it survives into the simulated objective.
        let mut giant = Dfg::new("giant");
        giant.push(OpKind::Linear { fin: 60_000, fout: 60_000 }, 1, "fc");
        let set = TenantSet::new(vec![giant], cost);
        let plan = DeploymentPlan::unregulated(1);
        assert!(set.hbm_pressure_us(&plan) > 0.0);
        let opts = crate::gpu::SimOptions::for_platform(&set.cost.platform);
        let out = set.simulate(&plan, opts);
        assert!(out.hbm_pressure_us > 0.0);
    }

    #[test]
    fn memory_aware_separates_bandwidth_hogs() {
        let cost = CostModel::new(Platform::titan_v());
        // Two bandwidth hogs (BN nets: mem ≈ 96 % each, floor occupancy)
        // and two low-occupancy conv fillers, with serial-latency weights
        // ≈ [4, 2.8, 2.8, 2] × u so plain LPT pairs the hogs on the
        // same device.
        let tenants = vec![
            bn_net("hog-a", 48),
            conv_net("lo-a", 1, 2),
            conv_net("lo-b", 1, 2),
            bn_net("hog-b", 24),
        ];
        let set = TenantSet::new(tenants, cost);
        let lb = Placement::balanced(&set, 2);
        assert_eq!(
            lb.device_of(0),
            lb.device_of(3),
            "precondition: LPT co-locates the bandwidth hogs"
        );
        // Occupancy-only interference sees slowdown 1.0 everywhere here
        // (the hogs barely hold SMs) and pairs them too.
        let ia = Placement::interference_aware(&set, 2);
        assert_eq!(
            ia.device_of(0),
            ia.device_of(3),
            "precondition: occupancy-only scoring is blind to the hogs"
        );
        let ma = Placement::memory_aware(&set, 2);
        ma.validate(4).unwrap();
        assert_ne!(ma.device_of(0), ma.device_of(3), "hogs split");
        let max = |v: Vec<f64>| v.into_iter().fold(0.0f64, f64::max);
        assert!(
            max(ma.predicted_slowdowns(&set)) < max(lb.predicted_slowdowns(&set)),
            "roofline max slowdown strictly reduced"
        );
        assert!(max(ma.memory_scores(&set)) < max(lb.memory_scores(&set)));
    }

    #[test]
    fn fit_memory_aware_prefers_fitting_devices_and_refuses_overflow() {
        let cost = CostModel::new(Platform::titan_v());
        let set = TenantSet::new(
            vec![bn_net("a", 4), conv_net("b", 1, 2)],
            cost,
        );
        let p = Placement::from_assignments(vec![vec![0], vec![1]]);
        // A small newcomer is placed somewhere valid.
        let ok = p.fit_memory_aware(&set, &conv_net("new", 1, 1)).unwrap();
        assert!(ok < 2);
        // A 14.4 GB tenant fits no 12 GB device: typed refusal.
        let mut giant = Dfg::new("giant");
        giant.push(OpKind::Linear { fin: 60_000, fout: 60_000 }, 1, "fc");
        let err = p.fit_memory_aware(&set, &giant).unwrap_err();
        assert!(matches!(err, Error::MemoryCapacity(_)), "got {err:?}");
        assert!(err.to_string().contains("giant"));
    }

    #[test]
    fn hbm_usage_sums_placed_footprints() {
        let (tenants, cost) = setup();
        let set = TenantSet::new(tenants, cost);
        let p = Placement::from_assignments(vec![vec![0, 2], vec![1]]);
        let usage = p.hbm_usage(&set);
        let f = |s: usize| set.hbm_footprint(s, None);
        assert!((usage[0] - (f(0) + f(2))).abs() < 1e-6);
        assert!((usage[1] - f(1)).abs() < 1e-6);
        assert!(usage.iter().all(|&u| u > 0.0));
    }

    #[test]
    fn predicted_slowdowns_are_free_without_colocation() {
        let (tenants, cost) = setup();
        let set = TenantSet::new(tenants, cost);
        // One tenant per device (plus an empty bin): nothing contends.
        let p = Placement::from_assignments(vec![vec![0], vec![1], vec![2], vec![]]);
        assert_eq!(p.predicted_slowdowns(&set), vec![1.0; 4]);
        let scores = p.interference_scores(&set);
        let loads = p.loads(&set);
        for (s, l) in scores.iter().zip(&loads) {
            assert!((s - l).abs() < 1e-9, "free co-location: score == load");
        }
    }

    #[test]
    fn least_interfering_avoids_the_saturated_device() {
        let cost = CostModel::new(Platform::titan_v());
        let d_hi = cost.cost_of(&mid_conv(), 32).duration_us;
        let d_lo = cost.cost_of(&mid_conv(), 1).duration_us;
        // Device 0 holds a saturating tenant (lighter load), device 1 a
        // low-occupancy tenant (heavier load).
        let n_lo = ((3.0 * d_hi) / d_lo).round() as usize;
        let tenants = vec![conv_net("hi", 32, 2), conv_net("lo", 1, n_lo.max(1))];
        let set = TenantSet::new(tenants, cost);
        let p = Placement::from_assignments(vec![vec![0], vec![1]]);
        // Raw load admission picks the saturated-but-lighter device...
        assert_eq!(p.least_loaded(&set), 0);
        // ...interference-scored admission sends a saturating newcomer to
        // the low-occupancy device instead.
        let newcomer = conv_net("hi-new", 32, 2);
        assert_eq!(p.least_interfering(&set, &newcomer), 1);
    }

    #[test]
    fn single_device_placement_degenerates() {
        let (tenants, cost) = setup();
        let set = TenantSet::new(tenants, cost);
        let p = Placement::balanced(&set, 1);
        assert_eq!(p, Placement::single_device(3));
        assert_eq!(p.tenants_on(0), &[0, 1, 2]);
    }

    #[test]
    fn uniform_pool_placements_match_the_n_devices_path() {
        let (tenants, cost) = setup();
        let set = TenantSet::new(tenants, cost);
        let pool = DevicePool::uniform(Platform::titan_v(), 2);
        assert_eq!(Placement::balanced_pool(&set, &pool), Placement::balanced(&set, 2));
        assert_eq!(
            Placement::interference_aware_pool(&set, &pool),
            Placement::interference_aware(&set, 2)
        );
        assert_eq!(
            Placement::memory_aware_pool(&set, &pool),
            Placement::memory_aware(&set, 2)
        );
        for objective in [
            PlacementObjective::LoadBalance,
            PlacementObjective::InterferenceAware,
            PlacementObjective::MemoryAware,
        ] {
            assert_eq!(
                Placement::with_objective_pool(&set, &pool, objective),
                Placement::with_objective(&set, 2, objective)
            );
        }
    }

    #[test]
    fn identity_scale_delegates_bit_for_bit() {
        let (tenants, cost) = setup();
        let newcomer = conv_net("new", 8, 3);
        let set = TenantSet::new(tenants, cost);
        let ones = vec![1.0; set.len()];
        for pool in [
            DevicePool::uniform(Platform::titan_v(), 2),
            DevicePool::from_platforms([Platform::a100(), Platform::t4()]),
        ] {
            for objective in [
                PlacementObjective::LoadBalance,
                PlacementObjective::InterferenceAware,
                PlacementObjective::MemoryAware,
            ] {
                assert_eq!(
                    Placement::with_objective_pool_scaled(&set, &pool, objective, &ones),
                    Placement::with_objective_pool(&set, &pool, objective)
                );
            }
            let p = Placement::with_objective_pool(
                &set,
                &pool,
                PlacementObjective::LoadBalance,
            );
            assert_eq!(
                p.least_loaded_pool_scaled(&set, &pool, &newcomer, &ones),
                p.least_loaded_pool(&set, &pool, &newcomer)
            );
            assert_eq!(
                p.least_interfering_pool_scaled(&set, &pool, &newcomer, &ones),
                p.least_interfering_pool(&set, &pool, &newcomer)
            );
            assert_eq!(
                p.fit_memory_aware_pool_scaled(&set, &pool, &newcomer, &ones).unwrap(),
                p.fit_memory_aware_pool(&set, &pool, &newcomer).unwrap()
            );
        }
    }

    #[test]
    fn scaled_placement_isolates_an_underpriced_tenant() {
        // Four identical tenants on two identical devices: the analytic
        // LPT pairs them 2/2. A trusted 3x correction on tenant 0 makes
        // it the heavy rock — the scaled LPT gives it a device alone.
        let tenants: Vec<Dfg> =
            (0..4).map(|i| conv_net(&format!("t{i}"), 8, 3)).collect();
        let set = TenantSet::new(tenants, CostModel::new(Platform::titan_v()));
        let pool = DevicePool::uniform(Platform::titan_v(), 2);
        let analytic = Placement::balanced_pool(&set, &pool);
        assert_eq!(analytic.tenants_on(0).len(), 2);
        let scaled = Placement::with_objective_pool_scaled(
            &set,
            &pool,
            PlacementObjective::LoadBalance,
            &[3.0, 1.0, 1.0, 1.0],
        );
        scaled.validate(4).unwrap();
        let d0 = scaled.device_of(0).unwrap();
        assert_eq!(
            scaled.tenants_on(d0),
            &[0],
            "the corrected-heavy tenant is placed alone"
        );
        assert_eq!(scaled.tenants_on(1 - d0).len(), 3);
    }

    #[test]
    fn scaled_admission_avoids_the_corrected_heavy_device() {
        // Two identical standing tenants, one per device. A 4x trusted
        // correction on tenant 0 must steer an identical newcomer onto
        // tenant 1's device even though analytic loads tie (tie-break
        // would pick device 0).
        let tenants: Vec<Dfg> =
            (0..2).map(|i| conv_net(&format!("t{i}"), 8, 3)).collect();
        let set = TenantSet::new(tenants, CostModel::new(Platform::titan_v()));
        let pool = DevicePool::uniform(Platform::titan_v(), 2);
        let p = Placement::from_assignments(vec![vec![0], vec![1]]);
        let newcomer = conv_net("new", 8, 3);
        assert_eq!(p.least_loaded_pool(&set, &pool, &newcomer), 0);
        let scale = [4.0, 1.0];
        assert_eq!(p.least_loaded_pool_scaled(&set, &pool, &newcomer, &scale), 1);
        assert_eq!(p.least_interfering_pool_scaled(&set, &pool, &newcomer, &scale), 1);
        assert_eq!(
            p.fit_memory_aware_pool_scaled(&set, &pool, &newcomer, &scale).unwrap(),
            1
        );
    }

    #[test]
    fn heterogeneous_balanced_gives_the_fast_device_more_work() {
        // Six identical tenants on an A100 + T4 pool: a count-blind 3/3
        // split leaves the T4 the bottleneck in wall-clock time; the
        // pool-aware LPT shifts work toward the A100 until the
        // *device-local* loads even out.
        let tenants: Vec<Dfg> =
            (0..6).map(|i| conv_net(&format!("t{i}"), 8, 3)).collect();
        let set = TenantSet::new(tenants, CostModel::new(Platform::a100()));
        let pool = DevicePool::from_platforms([Platform::a100(), Platform::t4()]);
        let p = Placement::balanced_pool(&set, &pool);
        p.validate(6).unwrap();
        assert!(
            p.tenants_on(0).len() > p.tenants_on(1).len(),
            "A100 takes more identical tenants than the T4, got {:?}/{:?}",
            p.tenants_on(0),
            p.tenants_on(1)
        );
        let max = |v: &[f64]| v.iter().copied().fold(0.0f64, f64::max);
        let naive = Placement::balanced(&set, 2);
        assert!(
            max(&p.loads_pool(&set, &pool)) < max(&naive.loads_pool(&set, &pool)),
            "pool-aware LPT lowers the wall-clock bottleneck"
        );
    }

    #[test]
    fn least_loaded_pool_prefers_the_fast_empty_device() {
        let set = TenantSet::new(Vec::new(), CostModel::new(Platform::a100()));
        // T4 first: a speed-blind chooser would tie-break onto it.
        let pool = DevicePool::from_platforms([Platform::t4(), Platform::a100()]);
        let p = Placement::from_assignments(vec![vec![], vec![]]);
        let newcomer = conv_net("new", 8, 3);
        assert_eq!(p.least_loaded_pool(&set, &pool, &newcomer), 1);
    }

    #[test]
    fn fit_memory_aware_pool_enforces_each_devices_own_capacity() {
        let cost = CostModel::new(Platform::a100());
        let set = TenantSet::new(vec![conv_net("a", 1, 2), conv_net("b", 1, 2)], cost);
        let pool = DevicePool::from_platforms([Platform::t4(), Platform::a100()]);
        let p = Placement::from_assignments(vec![vec![0], vec![1]]);
        // ~19.6 GB tenant: over the T4's 16 GB, within the A100's 40 GB.
        let mut giant = Dfg::new("giant");
        giant.push(OpKind::Linear { fin: 70_000, fout: 70_000 }, 1, "fc");
        assert_eq!(p.fit_memory_aware_pool(&set, &pool, &giant).unwrap(), 1);
        // ~57.6 GB fits nobody: typed refusal naming the pool.
        let mut huge = Dfg::new("huge");
        huge.push(OpKind::Linear { fin: 120_000, fout: 120_000 }, 1, "fc");
        let err = p.fit_memory_aware_pool(&set, &pool, &huge).unwrap_err();
        assert!(matches!(err, Error::MemoryCapacity(_)), "got {err:?}");
        assert!(err.to_string().contains("huge"));
    }

    #[test]
    fn pool_scores_price_each_device_with_its_own_roofline() {
        // Batch-8 mid convs: ~78 % occupancy on a T4's 40-SM pool,
        // ~39 % on an A100's 108 — the pair overflows the T4 only.
        let set = TenantSet::new(
            vec![conv_net("a", 8, 2), conv_net("b", 8, 2)],
            CostModel::new(Platform::a100()),
        );
        let pool = DevicePool::from_platforms([Platform::a100(), Platform::t4()]);
        // The same pair on each device in turn: the T4 predicts a
        // strictly worse slowdown than the A100 for the identical group.
        let on_fast = Placement::from_assignments(vec![vec![0, 1], vec![]]);
        let on_slow = Placement::from_assignments(vec![vec![], vec![0, 1]]);
        let fast = on_fast.predicted_slowdowns_pool(&set, &pool)[0];
        let slow = on_slow.predicted_slowdowns_pool(&set, &pool)[1];
        assert!(
            slow > fast,
            "T4 slowdown {slow} should exceed A100 slowdown {fast}"
        );
        assert!(
            on_slow.memory_scores_pool(&set, &pool)[1]
                > on_fast.memory_scores_pool(&set, &pool)[0]
        );
    }

    #[test]
    fn push_and_remove_device_reshape_the_placement() {
        let mut p = Placement::from_assignments(vec![vec![0, 1], vec![2]]);
        p.push_device();
        assert_eq!(p.n_devices(), 3);
        assert!(p.tenants_on(2).is_empty());
        p.move_slot(2, 2);
        assert_eq!(p.remove_device(1), Vec::<usize>::new());
        assert_eq!(p.n_devices(), 2);
        assert_eq!(p.tenants_on(1), &[2], "survivor shifted down intact");
        p.validate(3).unwrap();
    }

    #[test]
    fn more_devices_than_tenants_leaves_empty_bins() {
        let (tenants, cost) = setup();
        let set = TenantSet::new(tenants, cost);
        let p = Placement::balanced(&set, 5);
        p.validate(3).unwrap();
        let occupied = (0..5).filter(|&d| !p.tenants_on(d).is_empty()).count();
        assert_eq!(occupied, 3, "each tenant alone on its own device");
        let sharded = ShardedDeploymentPlan::unregulated(p);
        let (tenants, _) = setup();
        sharded.validate(&tenants).unwrap();
    }

    #[test]
    fn placement_validate_rejects_overlap_missing_range() {
        // Overlap: slot 1 on both devices.
        let p = Placement::from_assignments(vec![vec![0, 1], vec![1, 2]]);
        assert!(matches!(p.validate(3), Err(Error::InvalidPlan(_))));
        // Missing: slot 2 nowhere.
        let p = Placement::from_assignments(vec![vec![0], vec![1]]);
        assert!(matches!(p.validate(3), Err(Error::InvalidPlan(_))));
        // Out of range.
        let p = Placement::from_assignments(vec![vec![0, 3], vec![1, 2]]);
        assert!(matches!(p.validate(3), Err(Error::InvalidPlan(_))));
        // Zero devices.
        let p = Placement::from_assignments(Vec::new());
        assert!(p.validate(0).is_err());
        // A valid partition passes.
        let p = Placement::from_assignments(vec![vec![2, 0], vec![1]]);
        p.validate(3).unwrap();
        assert_eq!(p.tenants_on(0), &[0, 2], "lists kept sorted");
        assert_eq!(p.locate(2), Some((0, 1)));
        assert_eq!(p.device_of(1), Some(1));
    }

    #[test]
    fn placement_assign_and_remove_shift_slots() {
        let mut p = Placement::from_assignments(vec![vec![0, 2], vec![1]]);
        p.assign(3, 1);
        p.validate(4).unwrap();
        assert_eq!(p.tenants_on(1), &[1, 3]);
        // Evicting global slot 1 (device 1): later slots shift down.
        assert_eq!(p.remove_slot(1), Some(1));
        p.validate(3).unwrap();
        assert_eq!(p.tenants_on(0), &[0, 1], "old slot 2 became 1");
        assert_eq!(p.tenants_on(1), &[2], "old slot 3 became 2");
        // Removing an unplaced slot reports None.
        assert_eq!(p.remove_slot(9), None);
    }

    #[test]
    fn sharded_validate_checks_shards_and_placement() {
        let (tenants, cost) = setup();
        let set = TenantSet::new(tenants.clone(), cost);
        let placement = Placement::balanced(&set, 2);
        let mut sharded = ShardedDeploymentPlan::unregulated(placement.clone());
        sharded.validate(&tenants).unwrap();

        // Shard count mismatch.
        sharded.shards.pop();
        assert!(matches!(
            sharded.validate(&tenants),
            Err(Error::InvalidPlan(_))
        ));

        // A shard plan invalid against its local tenants (bad chunk sum).
        let mut sharded = ShardedDeploymentPlan::unregulated(placement.clone());
        sharded.shards[0].chunking[0].insert(0, vec![1, 2]);
        assert!(sharded.validate(&tenants).is_err());

        // Overlapping placement is rejected before shard checks.
        let mut bad = ShardedDeploymentPlan::unregulated(placement);
        bad.placement = Placement::from_assignments(vec![vec![0, 1], vec![1, 2]]);
        assert!(bad.validate(&tenants).is_err());
    }

    #[test]
    fn merged_projects_shards_to_global_slots() {
        let (tenants, _) = setup();
        // Fixed placement: device 0 = {0, 2}, device 1 = {1}.
        let placement = Placement::from_assignments(vec![vec![0, 2], vec![1]]);
        let mut sharded = ShardedDeploymentPlan::unregulated(placement);
        // Local tenant 1 of device 0 is global slot 2.
        sharded.shards[0].pointers.set_list(1, vec![4]);
        sharded.shards[0].chunking[1].insert(0, vec![4, 4]);
        // Local tenant 0 of device 1 is global slot 1.
        sharded.shards[1].pointers.set_list(0, vec![7]);
        sharded.validate(&tenants).unwrap();

        let merged = sharded.merged().unwrap();
        merged.validate(&tenants).unwrap();
        assert_eq!(merged.pointers.list(0), &[] as &[usize]);
        assert_eq!(merged.pointers.list(1), &[7]);
        assert_eq!(merged.pointers.list(2), &[4]);
        assert_eq!(merged.chunking[2].get(&0), Some(&vec![4, 4]));
        assert!(merged.chunking[0].is_empty());
    }

    #[test]
    fn tenant_set_shard_selects_local_tenants() {
        let (tenants, cost) = setup();
        let set = TenantSet::new(tenants.clone(), cost);
        let placement = Placement::from_assignments(vec![vec![0, 2], vec![1]]);
        let d0 = set.shard(&placement, 0);
        assert_eq!(d0.len(), 2);
        assert_eq!(d0.tenants[0].name, tenants[0].name);
        assert_eq!(d0.tenants[1].name, tenants[2].name);
        let d1 = set.shard(&placement, 1);
        assert_eq!(d1.len(), 1);
        assert_eq!(d1.tenants[0].name, tenants[1].name);
    }

    #[test]
    fn move_slot_rehomes_without_compaction() {
        let mut p = Placement::from_assignments(vec![vec![0, 2], vec![1]]);
        assert_eq!(p.move_slot(2, 1), Some(0));
        p.validate(3).unwrap();
        assert_eq!(p.tenants_on(0), &[0]);
        assert_eq!(p.tenants_on(1), &[1, 2], "global slots unchanged");
        // Moving onto the same device is a no-op.
        assert_eq!(p.move_slot(1, 1), Some(1));
        assert_eq!(p.tenants_on(1), &[1, 2]);
        // Unplaced slots report None.
        assert_eq!(p.move_slot(9, 0), None);
    }

    #[test]
    fn insert_tenant_lands_mid_plan() {
        let mut plan = DeploymentPlan::unregulated(2);
        plan.pointers.set_list(0, vec![3]);
        plan.pointers.set_list(1, vec![5]);
        // A migrated tenant whose global slot sorts between the two.
        plan.insert_tenant(1, 10, 1);
        assert_eq!(plan.chunking.len(), 3);
        assert_eq!(plan.pointers.list(0), &[3]);
        assert_eq!(plan.pointers.list(1).len(), 1, "seeded at current level");
        assert_eq!(plan.pointers.list(2), &[5], "old slot 1 shifted up");
    }

    #[test]
    fn changed_tenants_reports_exact_slots() {
        let (tenants, _) = setup();
        let old = DeploymentPlan::unregulated(3);
        assert!(old.changed_tenants(&old).is_empty());
        let mut new = old.clone();
        new.pointers.set_list(2, vec![4]);
        new.chunking[0].insert(0, vec![4, 4]);
        assert_eq!(new.changed_tenants(&old), vec![0, 2]);
        new.validate(&tenants).unwrap();
        // Arity mismatch: the extra slot counts as changed.
        let mut grown = old.clone();
        grown.push_tenant(12, 0);
        assert_eq!(grown.changed_tenants(&old), vec![3]);
    }

    #[test]
    fn changed_devices_tracks_membership_and_shards() {
        let p = Placement::from_assignments(vec![vec![0, 1], vec![2]]);
        let old = ShardedDeploymentPlan::unregulated(p);
        assert!(old.changed_devices(&old).is_empty());
        // A re-searched shard changes its device only.
        let mut new = old.clone();
        new.shards[1].pointers.set_list(0, vec![2]);
        assert_eq!(new.changed_devices(&old), vec![1]);
        // A migration changes exactly the two affected devices.
        let mut moved = old.clone();
        moved.placement.move_slot(1, 1);
        moved.shards[0] = DeploymentPlan::unregulated(1);
        moved.shards[1] = DeploymentPlan::unregulated(2);
        assert_eq!(moved.changed_devices(&old), vec![0, 1]);
    }

    #[test]
    fn changed_devices_after_evict_reflects_slot_renumbering() {
        // Evicting slot 1 (device 0) compacts device 1's slots 2 -> 1:
        // the slot-number diff reports BOTH devices, by design — device
        // 1's membership list renumbered even though its tenant and
        // shard plan are untouched (the serving-path diff in
        // ClusterServer::apply compares lowered specs and is immune).
        let old = ShardedDeploymentPlan::unregulated(Placement::from_assignments(
            vec![vec![0, 1], vec![2]],
        ));
        let mut evicted = old.clone();
        evicted.placement.remove_slot(1);
        evicted.shards[0] = DeploymentPlan::unregulated(1);
        assert_eq!(evicted.changed_devices(&old), vec![0, 1]);
    }

    #[test]
    fn push_and_remove_tenant_reshape_the_plan() {
        let (tenants, _) = setup();
        let mut plan = DeploymentPlan::unregulated(3);
        plan.pointers.set_list(0, vec![5]);
        plan.pointers.set_list(1, vec![7]);
        plan.pointers.set_list(2, vec![9]);
        // Admit a 4th tenant at the current pointer level: it gets one
        // evenly seeded pointer.
        let extra = zoo::build_default("M3").unwrap();
        plan.push_tenant(extra.len(), plan.pointers.pointers_per_tenant());
        let mut grown = tenants.clone();
        grown.push(extra);
        plan.validate(&grown).unwrap();
        assert_eq!(plan.chunking.len(), 4);
        assert_eq!(plan.pointers.list(3).len(), 1);
        // Evict tenant 1: plan shrinks and stays valid for the survivors.
        plan.remove_tenant(1);
        grown.remove(1);
        plan.validate(&grown).unwrap();
        assert_eq!(plan.pointers.list(0), &[5]);
        assert_eq!(plan.pointers.list(1), &[9], "slots shift down");
    }
}
