//! Deployment plans: the joint spatial/temporal configuration GACER
//! searches over, and its compilation to simulator streams.
//!
//! A [`DeploymentPlan`] carries the paper's three decision structures:
//! the decomposition `mask` + `list_B` per operator (§4.2) and the pointer
//! matrix `Matrix_P` (§4.3). [`TenantSet::compile`] lowers tenants + plan
//! into per-stream [`SimOp`] sequences, inserting the chunk/concat overhead
//! operators that batch decomposition costs and assigning each op its
//! segment (cluster) index from the pointer positions.

use std::collections::BTreeMap;


use crate::dfg::{Dfg, OpId, OpKind};
use crate::error::{Error, Result};
use crate::gpu::{SimOp, SimStage};
use crate::profile::CostModel;
use crate::temporal::PointerMatrix;

/// Per-tenant batch-decomposition choices: `op id -> list_B` (Eq. 5).
/// An absent entry is `mask(O) = 0` (no decomposition).
pub type ChunkMap = BTreeMap<OpId, Vec<usize>>;

/// The joint spatial + temporal deployment configuration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DeploymentPlan {
    /// Spatial: one chunk map per tenant (the mask + `list_B` of §4.2).
    pub chunking: Vec<ChunkMap>,
    /// Temporal: the pointer matrix `Matrix_P` of §4.3.
    pub pointers: PointerMatrix,
}

impl DeploymentPlan {
    /// The unregulated plan (Stream-Parallel's configuration).
    pub fn unregulated(n_tenants: usize) -> Self {
        DeploymentPlan {
            chunking: vec![ChunkMap::new(); n_tenants],
            pointers: PointerMatrix::empty(n_tenants),
        }
    }

    /// Total number of decomposed operators (the mask's popcount).
    pub fn decomposed_ops(&self) -> usize {
        self.chunking.iter().map(|m| m.len()).sum()
    }

    /// Validate against a tenant set: chunk lists must sum to the op's
    /// batch (Eq. 5's constraint) and pointer positions must be in range.
    pub fn validate(&self, tenants: &[Dfg]) -> Result<()> {
        let bad = |m: String| Err(Error::InvalidPlan(m));
        if self.chunking.len() != tenants.len() {
            return bad(format!(
                "plan has {} chunk maps for {} tenants",
                self.chunking.len(),
                tenants.len()
            ));
        }
        for (ti, (map, dfg)) in self.chunking.iter().zip(tenants).enumerate() {
            for (&op, list_b) in map {
                let Some(o) = dfg.ops.get(op) else {
                    return bad(format!("tenant {ti}: chunk map references op {op}"));
                };
                if list_b.is_empty() || list_b.iter().any(|&b| b == 0) {
                    return bad(format!("tenant {ti} op {op}: empty/zero chunk"));
                }
                let sum: usize = list_b.iter().sum();
                if sum != o.batch {
                    return bad(format!(
                        "tenant {ti} op {op}: list_B sums to {sum}, batch is {}",
                        o.batch
                    ));
                }
                if !o.chunkable() && list_b.len() > 1 {
                    return bad(format!("tenant {ti} op {op}: not chunkable"));
                }
            }
        }
        self.pointers.validate(tenants)
    }

    /// Grow the plan for a newly admitted tenant: an empty chunk map and a
    /// pointer list seeded with `n_pointers` evenly spread positions (the
    /// paper keeps `|P|` equal across tenants, so an incremental re-search
    /// starts the newcomer at the deployment's current pointer level).
    pub fn push_tenant(&mut self, dfg_len: usize, n_pointers: usize) {
        self.chunking.push(ChunkMap::new());
        // A DFG with fewer than 2 ops has no legal pointer position
        // (valid range is 1..len): it joins as a single segment.
        let seeded: Vec<usize> = if dfg_len < 2 {
            Vec::new()
        } else {
            (1..=n_pointers)
                .map(|j| (j * dfg_len / (n_pointers + 1)).clamp(1, dfg_len - 1))
                .collect()
        };
        self.pointers.push_tenant(seeded);
    }

    /// Drop tenant `i`'s chunk map and pointer list (eviction).
    pub fn remove_tenant(&mut self, i: usize) {
        self.chunking.remove(i);
        self.pointers.remove_tenant(i);
    }
}

/// A set of tenant DFGs deployed together, with the cost model that prices
/// their operators.
///
/// The set **owns** its DFGs: the engine admits and evicts tenants at
/// runtime, so the deployed population cannot be a borrow of some longer-
/// lived slice. (Cloning a DFG is cheap — a name plus a flat operator
/// list.)
pub struct TenantSet {
    pub tenants: Vec<Dfg>,
    pub cost: CostModel,
}

impl TenantSet {
    pub fn new(tenants: Vec<Dfg>, cost: CostModel) -> Self {
        TenantSet { tenants, cost }
    }

    /// Number of deployed tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Add a tenant; returns its slot index.
    pub fn admit(&mut self, dfg: Dfg) -> usize {
        self.tenants.push(dfg);
        self.tenants.len() - 1
    }

    /// Remove the tenant at `index` (later slots shift down).
    pub fn evict(&mut self, index: usize) -> Dfg {
        self.tenants.remove(index)
    }

    /// Lower tenants + plan to staged simulator streams.
    ///
    /// A decomposed operator becomes one fork-join stage whose micro-batch
    /// pieces issue concurrently (the paper deploys decomposed copies on
    /// parallel streams, Table 3). Consecutive ops decomposed with the
    /// SAME `list_B` chain: the activation stays split (`torch.chunk` is a
    /// view), so the `Chunk` overhead is paid once at the region entry and
    /// the `Concat` once at its exit — not per operator. All inserted ops
    /// inherit the source op's segment ("decomposed operators are inserted
    /// between the pointers without affecting `Matrix_P`", §4.4).
    pub fn compile(&self, plan: &DeploymentPlan) -> Vec<Vec<SimStage>> {
        self.tenants
            .iter()
            .enumerate()
            .map(|(ti, dfg)| {
                let empty = ChunkMap::new();
                let chunks = plan.chunking.get(ti).unwrap_or(&empty);
                let pointers = plan.pointers.list(ti);
                let mut stream: Vec<SimStage> = Vec::with_capacity(dfg.len());
                let mut open_split: Option<&Vec<usize>> = None;
                for op in &dfg.ops {
                    // Segment = number of pointers at positions <= op.id.
                    let segment = pointers.iter().filter(|&&p| p <= op.id).count();
                    let split = chunks.get(&op.id).filter(|l| l.len() > 1);
                    // Close an open split region on change/end. The concat
                    // belongs to the previous op (its segment follows that
                    // op's pointer count) so segment restamping from
                    // `source_op` stays exact.
                    if let Some(prev) = open_split {
                        if split != Some(prev) {
                            let elems = dfg.ops[op.id - 1].kind.out_elems();
                            let prev_segment =
                                pointers.iter().filter(|&&p| p <= op.id - 1).count();
                            stream.push(SimStage::solo(self.sim_op(
                                &OpKind::Concat { elems },
                                dfg.ops[op.id - 1].batch,
                                prev_segment,
                                op.id - 1,
                            )));
                            open_split = None;
                        }
                    }
                    match split {
                        Some(list_b) => {
                            if open_split.is_none() {
                                let elems = op.kind.out_elems();
                                stream.push(SimStage::solo(self.sim_op(
                                    &OpKind::Chunk { elems },
                                    op.batch,
                                    segment,
                                    op.id,
                                )));
                                open_split = Some(list_b);
                            }
                            let pieces = list_b
                                .iter()
                                .map(|&b| self.sim_op(&op.kind, b, segment, op.id))
                                .collect();
                            stream.push(SimStage { pieces });
                        }
                        None => stream.push(SimStage::solo(self.sim_op(
                            &op.kind, op.batch, segment, op.id,
                        ))),
                    }
                }
                if open_split.is_some() {
                    let last = dfg.ops.last().unwrap();
                    let elems = last.kind.out_elems();
                    let segment = pointers.iter().filter(|&&p| p <= last.id).count();
                    stream.push(SimStage::solo(self.sim_op(
                        &OpKind::Concat { elems },
                        last.batch,
                        segment,
                        last.id,
                    )));
                }
                stream
            })
            .collect()
    }

    fn sim_op(&self, kind: &OpKind, batch: usize, segment: usize, source: OpId) -> SimOp {
        let c = self.cost.cost_of(kind, batch);
        SimOp {
            occupancy: c.sm_occupancy,
            duration_us: c.duration_us,
            mem_util: c.mem_util,
            segment,
            source_op: source,
            class: kind.class(),
        }
    }

    /// Compile with every tenant in its own single-segment stream — the
    /// raw Stream-Parallel lowering (flat: one SimOp per operator).
    pub fn compile_unregulated(&self) -> Vec<Vec<SimOp>> {
        self.compile(&DeploymentPlan::unregulated(self.tenants.len()))
            .into_iter()
            .map(|stages| stages.into_iter().flat_map(|st| st.pieces).collect())
            .collect()
    }

    /// Compile + simulate a plan under `opts` — the modeling-based
    /// evaluation every regulation step uses (no hardware profiling per
    /// candidate, §4.4 "Search Cost Analysis").
    pub fn simulate(
        &self,
        plan: &DeploymentPlan,
        opts: crate::gpu::SimOptions,
    ) -> crate::gpu::SimOutcome {
        crate::gpu::GpuSim::new(opts).run_staged(&self.compile(plan))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::profile::Platform;

    fn setup() -> (Vec<Dfg>, CostModel) {
        let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
        (tenants, CostModel::new(Platform::titan_v()))
    }

    #[test]
    fn unregulated_compiles_one_simop_per_op() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let streams = ts.compile_unregulated();
        for (s, d) in streams.iter().zip(&tenants) {
            assert_eq!(s.len(), d.len());
            assert!(s.iter().all(|o| o.segment == 0));
        }
    }

    #[test]
    fn chunking_forks_one_stage_with_overhead() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        // Chunk V16's first conv (tenant 1, op 0) into 2 pieces.
        plan.chunking[1].insert(0, vec![4, 4]);
        plan.validate(&tenants).unwrap();
        let streams = ts.compile(&plan);
        // +1 chunk stage, +1 concat stage (pieces share one fork stage).
        assert_eq!(streams[1].len(), tenants[1].len() + 2);
        assert_eq!(streams[1][0].pieces[0].class, "chunk");
        assert_eq!(streams[1][1].pieces.len(), 2, "fork stage has 2 pieces");
        assert_eq!(streams[1][2].pieces[0].class, "concat");
    }

    #[test]
    fn adjacent_chunked_ops_chain_one_overhead_pair() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        // V16 ops 0 (conv) and 1 (relu) chunked identically: the split
        // region opens once and closes once.
        plan.chunking[1].insert(0, vec![4, 4]);
        plan.chunking[1].insert(1, vec![4, 4]);
        let streams = ts.compile(&plan);
        let classes: Vec<&str> = streams[1]
            .iter()
            .flat_map(|st| st.pieces.iter().map(|p| p.class))
            .collect();
        assert_eq!(classes.iter().filter(|c| **c == "chunk").count(), 1);
        assert_eq!(classes.iter().filter(|c| **c == "concat").count(), 1);
        assert_eq!(streams[1].len(), tenants[1].len() + 2);
    }

    #[test]
    fn chunk_pieces_have_lower_occupancy() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        plan.chunking[1].insert(2, vec![2, 2, 2, 2]);
        let full = ts.compile_unregulated()[1][2].occupancy;
        let piece = ts.compile(&plan)[1][3].pieces[0].occupancy;
        assert!(piece <= full);
    }

    #[test]
    fn pointers_assign_segments() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        plan.pointers.set_list(0, vec![5, 10]);
        let streams = ts.compile(&plan);
        assert_eq!(streams[0][0].segment(), 0);
        assert_eq!(streams[0][5].segment(), 1);
        assert_eq!(streams[0][10].segment(), 2);
        assert_eq!(streams[0].last().unwrap().segment(), 2);
    }

    #[test]
    fn validate_rejects_bad_list_b() {
        let (tenants, _) = setup();
        let mut plan = DeploymentPlan::unregulated(3);
        plan.chunking[0].insert(0, vec![3, 3]); // batch is 8
        assert!(plan.validate(&tenants).is_err());
    }

    #[test]
    fn validate_rejects_wrong_tenant_count() {
        let (tenants, _) = setup();
        let plan = DeploymentPlan::unregulated(2);
        assert!(plan.validate(&tenants).is_err());
    }

    #[test]
    fn segments_monotone_within_stream() {
        let (tenants, cost) = setup();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        plan.pointers.set_list(1, vec![3, 9, 20]);
        for s in ts.compile(&plan) {
            for pair in s.windows(2) {
                assert!(pair[1].segment() >= pair[0].segment());
            }
        }
    }

    #[test]
    fn validate_rejects_multi_entry_list_on_non_chunkable_op() {
        // D121's dense blocks contain channel concats, which are not
        // batch-chunkable. A multi-entry list_B on one must be rejected; a
        // single-entry list (mask = 0 realization) stays legal.
        let tenants = vec![zoo::build_default("D121").unwrap()];
        let op = tenants[0]
            .ops
            .iter()
            .find(|o| !o.chunkable())
            .expect("D121 has a non-chunkable op");
        let (id, batch) = (op.id, op.batch);
        let mut plan = DeploymentPlan::unregulated(1);
        plan.chunking[0].insert(id, vec![batch / 2, batch - batch / 2]);
        assert!(matches!(
            plan.validate(&tenants),
            Err(crate::error::Error::InvalidPlan(_))
        ));
        plan.chunking[0].insert(id, vec![batch]);
        plan.validate(&tenants).unwrap();
    }

    #[test]
    fn validate_rejects_out_of_range_pointer() {
        let (tenants, _) = setup();
        let mut plan = DeploymentPlan::unregulated(3);
        plan.pointers.set_list(0, vec![tenants[0].len()]); // valid: 1..len
        assert!(plan.validate(&tenants).is_err());
        plan.pointers.set_list(0, vec![tenants[0].len() - 1]);
        plan.validate(&tenants).unwrap();
    }

    #[test]
    fn validate_rejects_unknown_op_and_zero_chunk() {
        let (tenants, _) = setup();
        let mut plan = DeploymentPlan::unregulated(3);
        plan.chunking[0].insert(10_000, vec![8]);
        assert!(plan.validate(&tenants).is_err());
        let mut plan = DeploymentPlan::unregulated(3);
        plan.chunking[0].insert(0, vec![8, 0]);
        assert!(plan.validate(&tenants).is_err());
    }

    #[test]
    fn push_and_remove_tenant_reshape_the_plan() {
        let (tenants, _) = setup();
        let mut plan = DeploymentPlan::unregulated(3);
        plan.pointers.set_list(0, vec![5]);
        plan.pointers.set_list(1, vec![7]);
        plan.pointers.set_list(2, vec![9]);
        // Admit a 4th tenant at the current pointer level: it gets one
        // evenly seeded pointer.
        let extra = zoo::build_default("M3").unwrap();
        plan.push_tenant(extra.len(), plan.pointers.pointers_per_tenant());
        let mut grown = tenants.clone();
        grown.push(extra);
        plan.validate(&grown).unwrap();
        assert_eq!(plan.chunking.len(), 4);
        assert_eq!(plan.pointers.list(3).len(), 1);
        // Evict tenant 1: plan shrinks and stays valid for the survivors.
        plan.remove_tenant(1);
        grown.remove(1);
        plan.validate(&grown).unwrap();
        assert_eq!(plan.pointers.list(0), &[5]);
        assert_eq!(plan.pointers.list(1), &[9], "slots shift down");
    }
}
