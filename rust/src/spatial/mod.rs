//! Spatial granularity regulation (§4.2): residue-targeted operator
//! resizing along the batch dimension.
//!
//! One regulation step follows the paper's "Overall Spatial Regulation":
//! simulate the current plan, find the time cycle with the biggest residue
//! `Max(R_{S_T})` (Eq. 2), pick the largest-occupancy chunkable operator
//! adjacent to it, and decompose a batch slice "that matches the residue
//! size" — i.e. choose micro-batch pieces whose occupancy fits the free
//! capacity of that cycle. Candidates are kept only if the re-simulated
//! residue (Eq. 8 — the simulator prices chunk/concat overhead and sync
//! waits physically) improves; tail residues that no decomposition can
//! fill are skipped, as §4.2 prescribes.

use std::collections::HashSet;

use crate::dfg::OpId;
use crate::gpu::{SimOptions, SimOutcome};
use crate::plan::{DeploymentPlan, TenantSet};

/// Stateful spatial regulator: remembers which operators it already tried
/// so alternating search rounds keep making progress.
pub struct SpatialRegulator {
    opts: SimOptions,
    tried: HashSet<(usize, OpId)>,
    /// Candidate ops examined per step (the largest-occupancy `k`).
    pub candidates_per_step: usize,
}

/// Outcome of one spatial step.
pub struct SpatialStep {
    pub plan: DeploymentPlan,
    pub outcome: SimOutcome,
    /// (tenant, op) that was decomposed.
    pub decomposed: (usize, OpId),
    /// The `list_B` chosen.
    pub list_b: Vec<usize>,
}

impl SpatialRegulator {
    pub fn new(opts: SimOptions) -> Self {
        SpatialRegulator { opts, tried: HashSet::new(), candidates_per_step: 6 }
    }

    /// Reset the tried-set (e.g. after temporal regulation reshuffled the
    /// schedule and previously useless decompositions may now pay off).
    pub fn reset_memory(&mut self) {
        self.tried.clear();
    }

    /// Attempt one decomposition step. Returns the improved plan, or
    /// `None` when no candidate improves the residue.
    pub fn step(&mut self, ts: &TenantSet, plan: &DeploymentPlan) -> Option<SpatialStep> {
        let mut opts = self.opts;
        opts.record_trace = true;
        opts.record_ops = true;
        let base = ts.simulate(plan, opts);
        let trace = base.trace.as_ref()?;
        let records = base.op_records.as_ref()?;

        // Biggest-residue interval (Max R_{S_T}).
        let mut best_iv: Option<(f64, f64, f64)> = None; // (start, end, free)
        let mut best_score = 0.0f64;
        for iv in trace.intervals() {
            let free = self.opts.sm_capacity - iv.occupancy;
            let score = free * (iv.end_us - iv.start_us);
            if free > 1.0 && score > best_score {
                best_score = score;
                best_iv = Some((iv.start_us, iv.end_us, free));
            }
        }
        let (iv_start, iv_end, free) = best_iv?;

        // Candidate ops: chunkable, untried, undecomposed, overlapping or
        // immediately following the residue interval; largest occupancy
        // first ("decompose the operator with the largest size").
        let mut cands: Vec<(f64, usize, OpId, usize)> = Vec::new(); // (w, tenant, op, batch)
        for r in records {
            if r.end_us <= iv_start || r.start_us >= iv_end + (iv_end - iv_start) {
                continue;
            }
            let tenant = r.stream;
            let op = ts.tenants[tenant].ops.get(r.source_op);
            let Some(op) = op else { continue };
            if !op.chunkable()
                || self.tried.contains(&(tenant, op.id))
                || plan
                    .chunking
                    .get(tenant)
                    .is_some_and(|m| m.contains_key(&op.id))
            {
                continue;
            }
            cands.push((r.occupancy, tenant, op.id, op.batch));
        }
        cands.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        cands.dedup_by_key(|c| (c.1, c.2));
        cands.truncate(self.candidates_per_step);

        // Evaluate candidate decompositions; keep the best improving one.
        let mut best: Option<SpatialStep> = None;
        for (_, tenant, op_id, batch) in cands {
            self.tried.insert((tenant, op_id));
            let Some(list_b) = self.pick_split(ts, tenant, op_id, batch, free) else {
                continue;
            };
            let mut cand_plan = plan.clone();
            cand_plan.chunking[tenant].insert(op_id, list_b.clone());
            let out = ts.simulate(&cand_plan, self.opts);
            if out.objective() < base.objective() - 1e-9
                && best
                    .as_ref()
                    .is_none_or(|b| out.objective() < b.outcome.objective())
            {
                best = Some(SpatialStep {
                    plan: cand_plan,
                    outcome: out,
                    decomposed: (tenant, op_id),
                    list_b: list_b.clone(),
                });
            }
        }
        best
    }

    /// Choose `list_B`: halve the batch until a piece's occupancy fits the
    /// residue ("decompose a batch that matches the residue size"). Prefer
    /// the coarsest split that fits (minimal chunk/concat overhead).
    fn pick_split(
        &self,
        ts: &TenantSet,
        tenant: usize,
        op_id: OpId,
        batch: usize,
        free: f64,
    ) -> Option<Vec<usize>> {
        let kind = ts.tenants[tenant].ops[op_id].kind;
        let mut piece = batch / 2;
        while piece >= 1 {
            let w = ts.cost.cost_of(&kind, piece).sm_occupancy;
            if w <= free || piece == 1 {
                let mut list = vec![piece; batch / piece];
                let rem = batch % piece;
                if rem > 0 {
                    list.push(rem);
                }
                // A split into >8 pieces is overhead-dominated; §4.2's
                // trade-off says stop.
                if list.len() > 8 {
                    return None;
                }
                return Some(list);
            }
            piece /= 2;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::profile::{CostModel, Platform};

    fn opts(p: &Platform) -> SimOptions {
        SimOptions::for_platform(p)
    }

    #[test]
    fn step_improves_residue_on_heavy_combo() {
        // R50+V16+M3: the combo the paper says spatial regulation helps
        // most (§5.2).
        let platform = Platform::titan_v();
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&["R50", "V16", "M3"]);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let plan = DeploymentPlan::unregulated(3);
        let base = ts.simulate(&plan, opts(&platform));
        let mut reg = SpatialRegulator::new(opts(&platform));
        let step = reg.step(&ts, &plan);
        if let Some(s) = step {
            assert!(s.outcome.objective() < base.objective());
            s.plan.validate(&tenants).unwrap();
        }
        // (If no single decomposition improves, that's legal; the search
        // integration test asserts end-to-end improvement.)
    }

    #[test]
    fn repeated_steps_monotone() {
        let platform = Platform::titan_v();
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(3);
        let mut reg = SpatialRegulator::new(opts(&platform));
        let mut last = ts.simulate(&plan, opts(&platform)).objective();
        for _ in 0..4 {
            match reg.step(&ts, &plan) {
                Some(s) => {
                    assert!(s.outcome.objective() <= last);
                    last = s.outcome.objective();
                    plan = s.plan;
                }
                None => break,
            }
        }
        plan.validate(&tenants).unwrap();
    }

    #[test]
    fn list_b_always_sums_to_batch() {
        let platform = Platform::titan_v();
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&["R50", "V16", "M3"]);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut reg = SpatialRegulator::new(opts(&platform));
        let mut plan = DeploymentPlan::unregulated(3);
        for _ in 0..5 {
            match reg.step(&ts, &plan) {
                Some(s) => {
                    let (t, o) = s.decomposed;
                    assert_eq!(
                        s.list_b.iter().sum::<usize>(),
                        tenants[t].ops[o].batch
                    );
                    plan = s.plan;
                }
                None => break,
            }
        }
    }

    #[test]
    fn tried_ops_not_retried() {
        let platform = Platform::titan_v();
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut reg = SpatialRegulator::new(opts(&platform));
        let plan = DeploymentPlan::unregulated(3);
        let mut seen = std::collections::HashSet::new();
        let mut p = plan;
        while let Some(s) = reg.step(&ts, &p) {
            assert!(seen.insert(s.decomposed), "op decomposed twice");
            p = s.plan;
            if seen.len() > 20 {
                break;
            }
        }
    }
}
