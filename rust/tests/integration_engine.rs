//! Integration: the `GacerEngine` deployment API — search → plan →
//! lowered server configuration, plus runtime admit/evict re-planning.
//!
//! The serving half requires `make artifacts` (and the `xla-runtime`
//! feature); those tests skip with a notice when artifacts are absent so a
//! bare checkout still passes `cargo test`.

use std::time::Duration;

use gacer::coordinator::BatchPolicy;
use gacer::engine::GacerEngine;
use gacer::models::zoo;
use gacer::plan::{DeploymentPlan, TenantSet};
use gacer::prelude::*;

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping engine serving test: run `make artifacts` first");
        None
    }
}

fn quick_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 6,
        spatial_steps_per_level: 2,
        ..Default::default()
    }
}

fn policy() -> BatchPolicy {
    BatchPolicy::new(8, Duration::from_millis(1), vec![1, 2, 4, 8, 16, 32])
}

#[test]
fn engine_search_never_worse_than_unregulated() {
    let engine = GacerEngine::builder()
        .search(quick_cfg())
        .tenant(zoo::build_default("R50").unwrap())
        .tenant(zoo::build_default("V16").unwrap())
        .tenant(zoo::build_default("M3").unwrap())
        .build()
        .unwrap();
    let r = engine.last_report().unwrap();
    assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
    engine.plan().validate(engine.tenants()).unwrap();
}

#[test]
fn seeded_research_preserves_plan_quality() {
    // run_from (the engine's incremental path) seeded with a cold search's
    // plan must never end up worse than that plan.
    let platform = Platform::titan_v();
    let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
    let ts = TenantSet::new(tenants, CostModel::new(platform));
    let search = GacerSearch::new(&ts, SimOptions::for_platform(&platform), quick_cfg());
    let cold = search.run();
    let seeded = search.run_from(cold.plan.clone()).unwrap();
    assert!(
        seeded.outcome.objective() <= cold.outcome.objective() + 1e-6,
        "seeded {} vs cold {}",
        seeded.outcome.objective(),
        cold.outcome.objective()
    );
    seeded.plan.validate(&ts.tenants).unwrap();
}

#[test]
fn admit_evict_cycle_keeps_plans_valid_and_competitive() {
    let mut engine = GacerEngine::builder()
        .search(quick_cfg())
        .tenant(zoo::build_default("R18").unwrap())
        .tenant(zoo::build_default("M3").unwrap())
        .build()
        .unwrap();
    let v16 = engine.admit(zoo::build_default("V16").unwrap()).unwrap();
    engine.plan().validate(engine.tenants()).unwrap();
    let r = engine.last_report().unwrap();
    assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);

    engine.evict(v16).unwrap();
    assert_eq!(engine.len(), 2);
    engine.plan().validate(engine.tenants()).unwrap();
    let r = engine.last_report().unwrap();
    assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
}

// ---- serving path (requires artifacts) ----

#[test]
fn lowered_deployment_reaches_the_running_scheduler() {
    // Acceptance: the searched plan's chunk sizes and issue order are what
    // the scheduler executes — asserted against the running server's
    // effective specs, not just the lowering output.
    let Some(dir) = artifacts_dir() else { return };
    let mut builder = GacerEngine::builder().search(quick_cfg()).artifacts(dir);
    for i in 0..3 {
        builder = builder
            .serving_tenant(format!("t{i}"), "tiny_cnn", policy())
            .unwrap();
    }
    let engine = builder.build().unwrap();
    let deployment = engine.deployment().unwrap();

    // The lowered issue order is a permutation derived from the plan.
    let mut sorted = deployment.config.issue_order.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, vec![0, 1, 2]);

    // Every lowered chunk is a compiled variant bounded by a searched
    // micro-batch piece of that tenant.
    for (i, spec) in deployment.tenants.iter().enumerate() {
        if let Some(c) = spec.chunk {
            let max_piece = engine.plan().chunking[i]
                .values()
                .filter(|l| l.len() > 1)
                .flat_map(|l| l.iter().copied())
                .max()
                .expect("chunk implies a searched decomposition");
            assert!(c <= max_piece, "chunk {c} exceeds searched piece {max_piece}");
        } else {
            assert!(
                engine.plan().chunking[i].values().all(|l| l.len() <= 1),
                "searched decomposition was dropped by the lowering"
            );
        }
    }

    let server = engine.serve().unwrap();
    assert_eq!(server.issue_order(), &deployment.config.issue_order[..]);
    for (spec, lowered) in server.tenant_specs().iter().zip(&deployment.tenants) {
        assert_eq!(spec.chunk, lowered.chunk);
        assert_eq!(spec.family, lowered.family);
    }

    // And it actually serves: one request per tenant, correct shape.
    for t in 0..3 {
        let x: Vec<f32> = (0..32 * 32 * 3)
            .map(|k| (((t * 7919 + k) % 97) as f32 / 97.0) - 0.5)
            .collect();
        let out = server.infer(t, x).unwrap();
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn unregulated_and_searched_deployments_agree_numerically() {
    // The engine's two lowerings of the same tenant set must compute the
    // same function (GACER regulates *how*, never *what*).
    let Some(dir) = artifacts_dir() else { return };
    let mut builder = GacerEngine::builder().search(quick_cfg()).artifacts(dir);
    for i in 0..2 {
        builder = builder
            .serving_tenant(format!("t{i}"), "tiny_cnn", policy())
            .unwrap();
    }
    let engine = builder.build().unwrap();
    let x: Vec<f32> = (0..32 * 32 * 3).map(|k| ((k % 97) as f32 / 97.0) - 0.5).collect();

    let searched = engine.serve().unwrap();
    let ys = searched.infer(0, x.clone()).unwrap();
    drop(searched);

    let unreg = engine
        .deployment_of(&DeploymentPlan::unregulated(engine.len()))
        .unwrap();
    let plain = gacer::coordinator::Server::start(dir, unreg.tenants, unreg.config).unwrap();
    let yp = plain.infer(0, x).unwrap();
    for (a, e) in ys.iter().zip(&yp) {
        assert!((a - e).abs() < 1e-3 + 1e-3 * e.abs(), "{a} vs {e}");
    }
}
