//! Memory-bandwidth contention acceptance tests: on a bandwidth-bound
//! mix, every occupancy-only placement objective (LPT load balance and
//! interference-aware scoring alike) pairs two HBM-saturating tenants
//! on one device; only the two-dimensional roofline
//! (`PlacementObjective::MemoryAware`) prices their combined bandwidth
//! demand and separates them — with a strictly lower predicted max
//! slowdown AND a lower simulated cluster makespan. Admission of a
//! tenant whose resident footprint exceeds every device's HBM returns
//! the typed `Error::MemoryCapacity` and leaves the engine untouched.

use gacer::bench_util::{compare_placements, memory_demo_mix, PlacementArm};
use gacer::dfg::{Dfg, OpKind};
use gacer::engine::GacerEngine;
use gacer::gpu::SimOptions;
use gacer::plan::{DeploymentPlan, Placement, PlacementObjective, TenantSet};
use gacer::profile::{CostModel, Platform};
use gacer::search::SearchConfig;
use gacer::Error;

fn demo_set() -> TenantSet {
    let platform = Platform::titan_v();
    TenantSet::new(memory_demo_mix(&platform), CostModel::new(platform))
}

fn quick_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 1,
        rounds_per_level: 1,
        positions_per_coordinate: 4,
        spatial_steps_per_level: 1,
        ..Default::default()
    }
}

/// A ~14.4 GB single-op tenant — larger than any supported device's HBM
/// (Titan V holds 12 GB), so memory-aware admission must refuse it.
fn giant() -> Dfg {
    let mut d = Dfg::new("giant");
    d.push(OpKind::Linear { fin: 60_000, fout: 60_000 }, 1, "fc");
    d
}

/// Max over devices of the simulated unregulated makespan when each
/// device runs exactly the tenants the placement assigns to it.
fn simulated_cluster_us(p: &Placement, set: &TenantSet) -> f64 {
    let opts = SimOptions::for_platform(&set.cost.platform);
    (0..p.n_devices())
        .map(|dev| {
            let tenants: Vec<Dfg> = p
                .tenants_on(dev)
                .iter()
                .map(|&slot| set.tenants[slot].clone())
                .collect();
            if tenants.is_empty() {
                return 0.0;
            }
            let n = tenants.len();
            let ts = TenantSet::new(tenants, set.cost.clone());
            ts.simulate(&DeploymentPlan::unregulated(n), opts).makespan_us
        })
        .fold(0.0f64, f64::max)
}

/// The mix's shape: the two BN nets saturate bandwidth while barely
/// holding SMs, and the serial-latency ordering tricks LPT into pairing
/// them — the blind spot this PR prices.
#[test]
fn demo_mix_preconditions_hold() {
    let set = demo_set();
    assert_eq!(set.len(), 4);
    assert_eq!(set.tenants[0].name, "hog-a");
    assert_eq!(set.tenants[3].name, "hog-b");
    // hog-a > lo-a ≈ lo-b > hog-b by serial latency: LPT pairs 0 and 3.
    let weights: Vec<f64> = set
        .tenants
        .iter()
        .map(|d| set.cost.sequential_latency_us(d))
        .collect();
    assert!(weights[0] > weights[1]);
    assert!(weights[2] > weights[3]);
    // Together the hogs oversubscribe HBM: roofline sees ~1.9×, the
    // occupancy-only model sees nothing.
    let pair = set.cost.colocation_slowdown(&[&set.tenants[0], &set.tenants[3]]);
    assert!(pair > 1.8, "paired-hog roofline slowdown = {pair}");
    let occ = set.cost.occupancy_slowdown(&[&set.tenants[0], &set.tenants[3]]);
    assert!(occ < 1.05, "occupancy-only slowdown = {occ}");
}

#[test]
fn occupancy_only_pairs_hogs_but_memory_aware_separates() {
    let set = demo_set();
    let lb = Placement::balanced(&set, 2);
    let ia = Placement::interference_aware(&set, 2);
    let ma = Placement::memory_aware(&set, 2);
    for p in [&lb, &ia, &ma] {
        p.validate(set.len()).unwrap();
    }

    // Both memory-blind objectives co-locate the hogs.
    assert_eq!(lb.device_of(0), lb.device_of(3), "LPT pairs the hogs");
    assert_eq!(
        ia.device_of(0),
        ia.device_of(3),
        "occupancy-only interference cannot see the bandwidth wall"
    );
    assert_ne!(ma.device_of(0), ma.device_of(3), "roofline splits them");

    // Strictly lower predicted max slowdown...
    let max = |v: Vec<f64>| v.into_iter().fold(0.0f64, f64::max);
    let ma_pred = max(ma.predicted_slowdowns(&set));
    assert!(ma_pred < max(lb.predicted_slowdowns(&set)));
    assert!(ma_pred < max(ia.predicted_slowdowns(&set)));
    // ...and a lower simulated cluster makespan: the simulator prices
    // bandwidth independently, so this is a second witness, not an echo
    // of the predictor.
    let ma_sim = simulated_cluster_us(&ma, &set);
    assert!(
        ma_sim < simulated_cluster_us(&lb, &set),
        "memory-aware must also win under simulation"
    );
    assert!(ma_sim < simulated_cluster_us(&ia, &set));
    // Every device stays within HBM capacity.
    let capacity = set.cost.platform.hbm_bytes();
    assert!(ma.hbm_usage(&set).iter().all(|&b| b <= capacity));
}

#[test]
fn bench_comparison_reports_the_win() {
    // The `gacer-bench memory` surface of the same acceptance check.
    let platform = Platform::titan_v();
    let arms = compare_placements(memory_demo_mix(&platform), &platform, 2);
    assert_eq!(arms.len(), 3);
    let (ia, ma) = (&arms[1], &arms[2]);
    assert_eq!(ia.objective, PlacementObjective::InterferenceAware);
    assert_eq!(ma.objective, PlacementObjective::MemoryAware);
    let together = |arm: &PlacementArm| {
        arm.per_device.iter().any(|d| {
            d.contains(&"hog-a".to_string()) && d.contains(&"hog-b".to_string())
        })
    };
    assert!(together(ia) && !together(ma));
    assert!(ma.max_slowdown() < ia.max_slowdown());
    // The occupancy-only column shows why the old model missed this:
    // it predicts a near-free cluster while the roofline sees ~1.9×.
    assert!(ia.max_occupancy_slowdown() < 1.05);
    assert!(ia.max_slowdown() > 1.5);
    assert!(arms.iter().all(|a| a.hbm_gb.iter().all(|&g| g > 0.0)));
}

#[test]
fn engine_memory_aware_placement_and_admission() {
    let platform = Platform::titan_v();
    let mut b = GacerEngine::builder()
        .devices(2)
        .placement_objective(PlacementObjective::MemoryAware)
        .search(quick_cfg());
    for dfg in memory_demo_mix(&platform) {
        b = b.tenant(dfg);
    }
    let mut engine = b.build().unwrap();
    assert_eq!(engine.placement_objective(), PlacementObjective::MemoryAware);
    let ids = engine.tenant_ids();
    assert_ne!(
        engine.device_of(ids[0]).unwrap(),
        engine.device_of(ids[3]).unwrap(),
        "engine's initial placement separates the hogs"
    );
    engine.sharded_plan().validate(engine.tenants()).unwrap();

    // A small newcomer fits and lands on the roofline-scored device.
    let before = engine.tenants().len();
    let newcomer = engine.tenants()[1].clone();
    engine.admit(newcomer).unwrap();
    assert_eq!(engine.tenants().len(), before + 1);
    engine.sharded_plan().validate(engine.tenants()).unwrap();

    // An over-capacity newcomer is refused with the typed error and
    // leaves no trace: tenant count, ids, and plan are unchanged.
    let before = engine.tenants().len();
    let ids = engine.tenant_ids();
    let err = engine.admit(giant()).unwrap_err();
    assert!(matches!(err, Error::MemoryCapacity(_)), "got {err:?}");
    assert!(err.to_string().contains("memory capacity"));
    assert_eq!(engine.tenants().len(), before);
    assert_eq!(engine.tenant_ids(), ids);
    engine.sharded_plan().validate(engine.tenants()).unwrap();
}

#[test]
fn single_device_degenerate_case() {
    // devices(1): nothing to separate — memory-aware placement is a
    // single valid bin and within-capacity admission still works.
    let set = demo_set();
    let p = Placement::memory_aware(&set, 1);
    p.validate(set.len()).unwrap();
    assert_eq!(p.n_devices(), 1);
    assert!((0..set.len()).all(|s| p.device_of(s) == Some(0)));

    let platform = Platform::titan_v();
    let mix = memory_demo_mix(&platform);
    let mut engine = GacerEngine::builder()
        .devices(1)
        .placement_objective(PlacementObjective::MemoryAware)
        .search(quick_cfg())
        .tenant(mix[1].clone())
        .build()
        .unwrap();
    engine.admit(mix[2].clone()).unwrap();
    assert_eq!(engine.tenants().len(), 2);
    engine.sharded_plan().validate(engine.tenants()).unwrap();
}
