//! Integration: model zoo -> cost model -> simulator, end to end, plus
//! the paper-shape assertions for the baseline orderings (§5.2).

use gacer::baselines::{Baseline, BaselineKind};
use gacer::gpu::{GpuSim, SimOptions};
use gacer::models::zoo;
use gacer::plan::{DeploymentPlan, TenantSet};
use gacer::profile::{CostModel, Platform};
use gacer::temporal::PointerMatrix;

fn opts(p: &Platform) -> SimOptions {
    SimOptions::for_platform(p)
}

#[test]
fn all_paper_combos_simulate_on_all_platforms() {
    for platform in Platform::all() {
        let cost = CostModel::new(platform);
        for combo in zoo::PAPER_COMBOS {
            let tenants = zoo::build_combo(&combo);
            let ts = TenantSet::new(tenants.clone(), cost.clone());
            let out = ts.simulate(&DeploymentPlan::unregulated(3), opts(&platform));
            assert!(out.makespan_us > 0.0);
            assert!(out.residue >= -1e-6);
            assert!(out.avg_utilization > 0.0 && out.avg_utilization <= 100.0);
        }
    }
}

#[test]
fn stream_parallel_beats_sequential_on_every_combo() {
    // Fig. 7's first-order claim.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    for combo in zoo::PAPER_COMBOS {
        let tenants = zoo::build_combo(&combo);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let b = Baseline::new(&ts, opts(&platform));
        let seq = b.run(BaselineKind::CudnnSeq);
        let sp = b.run(BaselineKind::StreamParallel);
        let speedup = seq.makespan_us / sp.makespan_us;
        assert!(
            (1.05..2.5).contains(&speedup),
            "{}: SP speedup {speedup}",
            zoo::combo_label(&combo)
        );
    }
}

#[test]
fn stream_parallel_speedup_in_paper_band() {
    // Paper: Stream-Parallel lands at roughly 1.2x-1.5x over CuDNN-Seq.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let mut in_band = 0;
    for combo in zoo::PAPER_COMBOS {
        let tenants = zoo::build_combo(&combo);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let b = Baseline::new(&ts, opts(&platform));
        let speedup = b.run(BaselineKind::CudnnSeq).makespan_us
            / b.run(BaselineKind::StreamParallel).makespan_us;
        if (1.15..=1.60).contains(&speedup) {
            in_band += 1;
        }
    }
    assert!(in_band >= 4, "only {in_band}/5 combos in the 1.15-1.6x band");
}

#[test]
fn sequential_utilization_is_low() {
    // Fig. 8: CuDNN-Seq shows the worst utilization.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&["R101", "D121", "M3"]);
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let b = Baseline::new(&ts, opts(&platform).with_trace());
    let seq = b.run(BaselineKind::CudnnSeq);
    let sp = b.run(BaselineKind::StreamParallel);
    assert!(seq.avg_utilization < sp.avg_utilization);
    assert!(seq.avg_utilization < 60.0, "seq util {}", seq.avg_utilization);
}

#[test]
fn pointer_barriers_cost_sync_time() {
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let mut plan = DeploymentPlan::unregulated(3);
    plan.pointers = PointerMatrix::equal_segments(&tenants, 4);
    let out = ts.simulate(&plan, opts(&platform));
    assert!(out.sync_idle_us > 0.0);
    // 3 cluster transitions at T_SW each.
    assert!((out.sync_idle_us - 3.0 * platform.sync_wait_us).abs() < 1e-6);
}

#[test]
fn operator_wise_scheduling_pays_heavy_sync_penalty() {
    // The right edge of Fig. 9.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&["R50", "V16", "M3"]);
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let coarse = ts.simulate(&DeploymentPlan::unregulated(3), opts(&platform));
    let mut fine = DeploymentPlan::unregulated(3);
    fine.pointers = PointerMatrix::operator_wise(&tenants);
    let fine_out = ts.simulate(&fine, opts(&platform));
    assert!(
        fine_out.makespan_us > coarse.makespan_us * 1.15,
        "operator-wise {} vs model-wise {}",
        fine_out.makespan_us,
        coarse.makespan_us
    );
}

#[test]
fn mps_is_unstable_across_combos() {
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let mut wins = 0;
    let mut losses = 0;
    for combo in zoo::PAPER_COMBOS {
        let tenants = zoo::build_combo(&combo);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let b = Baseline::new(&ts, opts(&platform));
        let mps = b.run(BaselineKind::Mps).makespan_us;
        let sp = b.run(BaselineKind::StreamParallel).makespan_us;
        if mps < sp {
            wins += 1;
        }
        if mps > sp * 1.01 {
            losses += 1;
        }
    }
    assert!(wins >= 1, "MPS should win somewhere");
    assert!(losses >= 1, "MPS should lose somewhere");
}

#[test]
fn empty_and_single_tenant_edge_cases() {
    let platform = Platform::titan_v();
    let out = GpuSim::new(opts(&platform)).run(&[]);
    assert_eq!(out.makespan_us, 0.0);

    let cost = CostModel::new(platform);
    let tenants = vec![zoo::build_default("Alex").unwrap()];
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let solo = ts.simulate(&DeploymentPlan::unregulated(1), opts(&platform));
    assert!((solo.makespan_us - cost.sequential_latency_us(&tenants[0])).abs() < 1e-6);
}

#[test]
fn slower_platforms_slower_absolute_latency() {
    // Table 2's cross-platform ordering.
    let mut last = 0.0;
    for platform in [Platform::titan_v(), Platform::p6000(), Platform::gtx_1080ti()] {
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&["R50", "V16", "M3"]);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let out = ts.simulate(&DeploymentPlan::unregulated(3), opts(&platform));
        assert!(out.makespan_us > last, "{} not slower", platform.name);
        last = out.makespan_us;
    }
}
