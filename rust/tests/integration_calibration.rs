//! End-to-end tests of the online cost-model calibration loop
//! (`gacer::calibrate` wired through the engine): measurement exposes a
//! mispricing no analytic objective can see, the correction changes a
//! real decision, and the trust ramp resets across evict/readmit so a
//! returning tenant can never inherit stale residuals.

use gacer::bench_util::calibration_sim::{
    bench_calibration_config, calibration_is_noop_without_observations, mis_modeled_mix,
    run_calibration_sim, CalibSimConfig,
};
use gacer::calibrate::CalibrationConfig;
use gacer::engine::{GacerEngine, MigrationPolicy};
use gacer::profile::{CostModel, Platform};
use gacer::search::SearchConfig;

fn small_search() -> SearchConfig {
    SearchConfig {
        max_pointers: 1,
        rounds_per_level: 1,
        positions_per_coordinate: 4,
        spatial_steps_per_level: 1,
        ..Default::default()
    }
}

fn calibrated_engine() -> GacerEngine {
    let mut b = GacerEngine::builder()
        .devices(2)
        .search(small_search())
        .calibration(CalibrationConfig::default());
    for t in mis_modeled_mix() {
        b = b.tenant(t);
    }
    b.build().expect("the demo mix builds")
}

/// The engine's predicted per-window latency for `slot` under the
/// current placement — the same number `record_latencies` compares
/// served samples against.
fn predicted_us(engine: &GacerEngine, slot: usize) -> f64 {
    let cost = CostModel::new(Platform::titan_v());
    let (device, _) = engine.placement().locate(slot).expect("placed");
    let tenants = engine.tenants();
    let cotenants: Vec<&gacer::dfg::Dfg> = engine
        .placement()
        .tenants_on(device)
        .iter()
        .copied()
        .filter(|&s| s != slot)
        .map(|s| &tenants[s])
        .collect();
    cost.predicted_colocated_latency_us(&tenants[slot], &cotenants)
}

/// Feed one observe window where every slot serves `multiplier[slot] ×`
/// its predicted latency (8 identical samples per slot).
fn feed_window(engine: &mut GacerEngine, multiplier: &[f64]) {
    let samples: Vec<Vec<f64>> = (0..engine.len())
        .map(|slot| vec![predicted_us(engine, slot) * multiplier[slot]; 8])
        .collect();
    engine.record_latencies(&samples).expect("slot-ordered samples");
}

#[test]
fn calibrated_migration_fires_where_the_analytic_policy_never_does() {
    // The full loop through the bench simulator: four analytically
    // identical tenants, one secretly `inflation ×` slower. The analytic
    // arm holds the 2+2 split forever; the calibrated arm's residuals
    // cross the trust ramp, the load-ratio policy fires, and the
    // mispriced tenant ends the run isolated — with a strictly better
    // worst-tenant p99 over the measurement windows.
    let analytic = run_calibration_sim(&CalibSimConfig::analytic());
    let calibrated = run_calibration_sim(&CalibSimConfig::calibrated());
    assert_eq!(analytic.migrated_window, None, "analytic weights stay balanced");
    assert!(!analytic.mis_isolated);
    assert!(calibrated.migrated_window.is_some(), "the correction must fire a move");
    assert!(calibrated.mis_isolated);
    assert!(
        calibrated.max_p99_us() < analytic.max_p99_us(),
        "calibrated worst p99 {} must strictly beat analytic {}",
        calibrated.max_p99_us(),
        analytic.max_p99_us()
    );
}

#[test]
fn migration_decision_flips_only_after_the_trust_ramp() {
    // Direct engine drive of the same effect, window by window: while
    // the residuals are still ramping the policy must decline (the
    // observed weights are analytic), and only once `min_samples`
    // windows have been folded in may the move fire.
    let mut engine = calibrated_engine();
    let policy = MigrationPolicy::default();
    let min_samples = CalibrationConfig::default().min_samples as usize;
    // Slot 0 secretly serves 6x its prediction; peers are accurate.
    let multiplier = [6.0, 1.0, 1.0, 1.0];
    let mut fired_at = None;
    for window in 0..6 {
        feed_window(&mut engine, &multiplier);
        let moved = engine.maybe_migrate(&policy).expect("consultation succeeds");
        if moved.is_some() && fired_at.is_none() {
            fired_at = Some(window);
        }
        if window + 1 < min_samples {
            assert_eq!(
                fired_at, None,
                "a move fired in window {window}, inside the trust ramp"
            );
        }
    }
    let fired_at = fired_at.expect("trusted residuals must eventually fire a move");
    assert!(fired_at + 1 >= min_samples);
    // The engine settled on the hidden truth: slot 0's correction is
    // well above 1 (6x clamped into the default [0.25, 4.0] band).
    let ids = engine.tenant_ids();
    let k = engine.correction_of(ids[0]).expect("id is live");
    assert!(k > 2.0, "mispriced tenant's correction is {k}");
}

#[test]
fn drift_then_recover_evict_readmit_resets_the_trust_ramp() {
    let mut engine = calibrated_engine();
    let ids = engine.tenant_ids();
    let drifter = ids[0];

    // Drift: tenant 0 serves 5x its prediction for enough windows to
    // complete the trust ramp. Its correction leaves 1.0.
    for _ in 0..4 {
        feed_window(&mut engine, &[5.0, 1.0, 1.0, 1.0]);
    }
    let drifted = engine.correction_of(drifter).expect("id is live");
    assert!(drifted > 1.0, "drift never registered: correction {drifted}");
    assert!(engine
        .calibration()
        .expect("calibrator attached")
        .is_trusted(drifter.0, "TitanV"));

    // Evict: every residual of the departed tenant is forgotten — the
    // calibrator holds nothing keyed to the old id.
    let dfg = engine.evict(drifter).expect("tenant is live");
    assert!(
        engine.corrections().iter().all(|e| e.tenant != drifter.0),
        "evict left residuals behind for tenant {drifter}"
    );

    // Readmit the same model: a fresh id, a fresh ramp. Decisions about
    // the returning tenant are analytic again until re-observed.
    let back = engine.admit(dfg).expect("readmission succeeds");
    assert_ne!(back, drifter, "tenant ids are never reused");
    assert_eq!(engine.correction_of(back).expect("id is live"), 1.0);
    assert!(!engine
        .calibration()
        .expect("calibrator attached")
        .is_trusted(back.0, "TitanV"));

    // Recover: the readmitted tenant now serves accurately. After the
    // ramp re-completes, its trusted correction sits at ~1.0 — the loop
    // converged back to the analytic model, not to the stale drift.
    let multiplier = vec![1.0; engine.len()];
    for _ in 0..4 {
        feed_window(&mut engine, &multiplier);
    }
    assert!(engine
        .calibration()
        .expect("calibrator attached")
        .is_trusted(back.0, "TitanV"));
    let recovered = engine.correction_of(back).expect("id is live");
    assert!(
        (recovered - 1.0).abs() < 1e-9,
        "recovered correction {recovered} should be ~1.0"
    );
}

#[test]
fn zero_observations_keep_every_decision_bit_for_bit_analytic() {
    // Acceptance criterion 2, at the integration level: enabling the
    // feature without feeding it changes nothing — placements, migration
    // consultations, re-plans, and admissions all match the analytic
    // twin exactly.
    assert!(calibration_is_noop_without_observations(3));
}

#[test]
fn bench_arm_config_is_stricter_than_default_only_in_its_clamp() {
    // Guard the bench knobs the acceptance criteria run under: same
    // trust ramp and EWMA as production defaults, wider clamp only.
    let bench = bench_calibration_config();
    let default = CalibrationConfig::default();
    assert_eq!(bench.min_samples, default.min_samples);
    assert_eq!(bench.alpha, default.alpha);
    assert_eq!(bench.min_correction, default.min_correction);
    assert!(bench.max_correction > default.max_correction);
}
