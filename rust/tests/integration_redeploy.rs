//! Integration: live re-deployment — plan diffing, load-drift tenant
//! migration, and (artifact-gated) hot plan swaps on running servers.
//!
//! The decision half (diff + migration) runs on the simulator substrate
//! and needs nothing but this repo. The serving half — the acceptance
//! criteria that a running `ClusterServer` absorbs an admit via
//! `redeploy` with no restart, and that no request is lost across a
//! swap — requires `make artifacts` (and the `xla-runtime` feature) and
//! skips with a notice when absent, like the other serving tests.

use std::sync::Arc;
use std::time::Duration;

use gacer::coordinator::BatchPolicy;
use gacer::models::zoo;
use gacer::prelude::*;

fn quick_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 5,
        spatial_steps_per_level: 2,
        ..Default::default()
    }
}

fn sharded_engine(names: &[&str], devices: usize) -> GacerEngine {
    let mut b = GacerEngine::builder().devices(devices).search(quick_cfg());
    for n in names {
        b = b.tenant(zoo::build_default(n).unwrap());
    }
    b.build().unwrap()
}

// ---- plan diffing ----

#[test]
fn plan_diff_is_empty_for_identical_plans() {
    let engine = sharded_engine(&["Alex", "V16", "R18"], 2);
    let plan = engine.sharded_plan();
    assert!(plan.changed_devices(plan).is_empty());
    assert!(engine.plan().changed_tenants(engine.plan()).is_empty());
}

#[test]
fn admit_diffs_one_device_and_unchanged_tenants_keep_identical_specs() {
    let mut engine = sharded_engine(&["R50", "V16", "R18", "M3"], 2);
    let before_sharded = engine.sharded_plan().clone();
    let before_merged = engine.plan().clone();

    let id = engine.admit(zoo::build_default("Alex").unwrap()).unwrap();
    let device = engine.device_of(id).unwrap();

    // Device-level diff: exactly the admitting device.
    assert_eq!(
        engine.sharded_plan().changed_devices(&before_sharded),
        vec![device]
    );
    // Tenant-level diff: every changed slot lives on the admitting
    // device (the newcomer always; co-tenants only if its re-search
    // moved them).
    let changed = engine.plan().changed_tenants(&before_merged);
    assert!(changed.contains(&(engine.len() - 1)), "newcomer is changed");
    for slot in &changed {
        assert_eq!(engine.placement().device_of(*slot), Some(device));
    }

    // Unchanged tenants lower to bit-identical serving specs: the
    // untouched device's lowered deployment is equal before and after,
    // which is exactly what lets ClusterServer::apply skip it.
    let other = 1 - device;
    let lower = |e: &GacerEngine, d: usize| {
        let tenants: Vec<Dfg> = e
            .placement()
            .tenants_on(d)
            .iter()
            .map(|&s| e.tenants()[s].clone())
            .collect();
        let policy = BatchPolicy::new(8, Duration::from_millis(1), vec![1, 2, 4, 8]);
        let specs: Vec<(String, String, BatchPolicy)> = tenants
            .iter()
            .map(|t| (t.name.clone(), "tiny_cnn".to_string(), policy.clone()))
            .collect();
        let variants = vec![vec![1, 2, 4, 8]; tenants.len()];
        gacer::engine::lower_plan(
            &e.sharded_plan().shards[d],
            &tenants,
            &specs,
            &variants,
            Duration::from_micros(200),
        )
        .unwrap()
    };
    let after = lower(&engine, other);
    // Reconstruct the "before" lowering from the saved plan (membership
    // on the untouched device is unchanged, so tenants/specs match).
    let before = {
        let tenants: Vec<Dfg> = before_sharded
            .placement
            .tenants_on(other)
            .iter()
            .map(|&s| engine.tenants()[s].clone())
            .collect();
        let policy = BatchPolicy::new(8, Duration::from_millis(1), vec![1, 2, 4, 8]);
        let specs: Vec<(String, String, BatchPolicy)> = tenants
            .iter()
            .map(|t| (t.name.clone(), "tiny_cnn".to_string(), policy.clone()))
            .collect();
        let variants = vec![vec![1, 2, 4, 8]; tenants.len()];
        gacer::engine::lower_plan(
            &before_sharded.shards[other],
            &tenants,
            &specs,
            &variants,
            Duration::from_micros(200),
        )
        .unwrap()
    };
    assert_eq!(after, before, "untouched device lowers identically");
}

// ---- load-drift migration (acceptance criterion 2, decision half) ----

#[test]
fn skewed_load_migrates_one_tenant_and_researches_only_two_shards() {
    // Three devices so a genuinely untouched shard exists.
    let mut engine = sharded_engine(&["R50", "V16", "R18", "M3", "Alex"], 3);
    let before = engine.sharded_plan().clone();
    let placement_before: Vec<Option<usize>> =
        (0..engine.len()).map(|s| engine.placement().device_of(s)).collect();

    // Drive skewed load: all traffic lands on one shared device.
    let hot_device = (0..3)
        .find(|&d| engine.placement().tenants_on(d).len() >= 2)
        .expect("5 tenants on 3 devices: some device shares");
    for (slot, id) in engine.tenant_ids().into_iter().enumerate() {
        if engine.placement().tenants_on(hot_device).contains(&slot) {
            engine.record_requests(id, 10_000).unwrap();
        }
    }
    let migration = engine
        .maybe_migrate(&MigrationPolicy::default())
        .unwrap()
        .expect("fully skewed load must trigger a migration");
    let from_d = engine.device_pool().index_of(migration.from).unwrap();
    let to_d = engine.device_pool().index_of(migration.to).unwrap();
    assert_eq!(from_d, hot_device);

    // Exactly one tenant changed device; its global slot is unchanged.
    let moved: Vec<usize> = (0..engine.len())
        .filter(|&s| engine.placement().device_of(s) != placement_before[s])
        .collect();
    assert_eq!(moved.len(), 1, "migration moves exactly one tenant");
    assert_eq!(engine.placement().device_of(moved[0]), Some(to_d));

    // Only the two affected shards were re-searched: the third device's
    // plan is bit-identical.
    assert_eq!(engine.last_searched_devices(), &[from_d, to_d]);
    for d in 0..3 {
        if d != from_d && d != to_d {
            assert_eq!(
                engine.sharded_plan().shards[d], before.shards[d],
                "uninvolved shard must not be re-searched"
            );
        }
    }
    let mut expected = vec![from_d, to_d];
    expected.sort_unstable();
    assert_eq!(engine.sharded_plan().changed_devices(&before), expected);
    engine.sharded_plan().validate(engine.tenants()).unwrap();
    engine.plan().validate(engine.tenants()).unwrap();
}

#[test]
fn balanced_load_never_migrates() {
    let mut engine = sharded_engine(&["Alex", "V16", "R18", "M3"], 2);
    // Uniform observed traffic mirrors the cost-balanced placement.
    for id in engine.tenant_ids() {
        engine.record_requests(id, 100).unwrap();
    }
    let before = engine.sharded_plan().clone();
    assert!(engine
        .maybe_migrate(&MigrationPolicy::default())
        .unwrap()
        .is_none());
    assert_eq!(engine.sharded_plan(), &before, "no-op leaves the plan alone");
}

// ---- hot swap on running servers (requires artifacts) ----

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping live-redeploy serving test: run `make artifacts` first");
        None
    }
}

fn policy() -> BatchPolicy {
    BatchPolicy::new(4, Duration::from_millis(1), vec![1, 2, 4, 8, 16, 32])
}

fn pseudo_input(seed: usize) -> Vec<f32> {
    (0..32 * 32 * 3)
        .map(|k| (((seed * 131 + k) % 97) as f32 / 97.0) - 0.5)
        .collect()
}

/// Acceptance criterion 1: admit against a running ClusterServer, call
/// redeploy with no restart, and serve correct results before and after
/// the swap.
#[test]
fn running_cluster_absorbs_admit_via_redeploy() {
    let Some(dir) = artifacts_dir() else { return };
    let mut b = GacerEngine::builder()
        .devices(2)
        .search(quick_cfg())
        .artifacts(dir);
    for i in 0..2 {
        b = b
            .serving_tenant(format!("t{i}"), "tiny_cnn", policy())
            .unwrap();
    }
    let mut engine = b.build().unwrap();
    let cluster = engine.serve_cluster().unwrap();

    // Serves before the swap — and pin a ground-truth row.
    let y_before = cluster.infer(0, pseudo_input(0)).unwrap();
    assert_eq!(y_before.len(), 10);
    assert_eq!(cluster.routing().len(), 2);

    // Admit against the RUNNING cluster; redeploy hot-swaps it in.
    engine
        .admit_serving("t2", "tiny_cnn", policy())
        .unwrap();
    let touched = engine.redeploy_cluster(&cluster).unwrap();
    let device = engine.device_of(engine.tenant_ids()[2]).unwrap();
    assert_eq!(touched, vec![device], "only the admitting device is swapped");
    assert_eq!(cluster.routing().len(), 3, "routing grew without a restart");

    // Serves after the swap: old tenants answer identically, the
    // newcomer answers at all.
    let y_after = cluster.infer(0, pseudo_input(0)).unwrap();
    for (a, e) in y_after.iter().zip(&y_before) {
        assert!((a - e).abs() < 1e-3 + 1e-3 * e.abs(), "{a} vs {e}");
    }
    let y_new = cluster.infer(2, pseudo_input(7)).unwrap();
    assert_eq!(y_new.len(), 10);
    assert!(y_new.iter().all(|v| v.is_finite()));

    // Idempotent redeploy: nothing changed, nothing is touched.
    assert!(engine.redeploy_cluster(&cluster).unwrap().is_empty());
}

/// Apply-mid-traffic invariant: no request is lost across a swap.
#[test]
fn no_request_lost_across_hot_swaps() {
    let Some(dir) = artifacts_dir() else { return };
    let mut b = GacerEngine::builder().search(quick_cfg()).artifacts(dir);
    for i in 0..2 {
        b = b
            .serving_tenant(format!("t{i}"), "tiny_cnn", policy())
            .unwrap();
    }
    let engine = b.build().unwrap();
    let server = Arc::new(engine.serve().unwrap());

    // Hammer both tenants from client threads while the main thread
    // repeatedly hot-swaps re-lowered plans (alternating issue orders).
    let n_per_client = 40;
    let mut clients = Vec::new();
    for t in 0..2 {
        let server = Arc::clone(&server);
        clients.push(std::thread::spawn(move || -> gacer::Result<usize> {
            let mut answered = 0;
            for i in 0..n_per_client {
                let out = server.infer(t, pseudo_input(t * 1_000 + i))?;
                assert_eq!(out.len(), 10);
                answered += 1;
            }
            Ok(answered)
        }));
    }
    let mut deployment = engine.deployment().unwrap();
    for swap in 0..6 {
        deployment.config.issue_order = if swap % 2 == 0 {
            vec![1, 0]
        } else {
            vec![0, 1]
        };
        server.apply(deployment.clone()).unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(server.epoch(), 6, "every fence committed");

    for c in clients {
        let answered = c.join().unwrap().unwrap();
        assert_eq!(answered, n_per_client, "every request answered");
    }
    let served = server.served_counts();
    assert_eq!(
        served.iter().sum::<u64>(),
        2 * n_per_client as u64,
        "counters survive swaps"
    );
}

/// A swap that removes a tenant flushes (answers) its queued work and
/// shifts later slots, mirroring engine eviction.
#[test]
fn evicting_swap_drains_the_removed_tenant() {
    let Some(dir) = artifacts_dir() else { return };
    let mut b = GacerEngine::builder().search(quick_cfg()).artifacts(dir);
    for i in 0..3 {
        b = b
            .serving_tenant(format!("t{i}"), "tiny_cnn", policy())
            .unwrap();
    }
    let mut engine = b.build().unwrap();
    let server = engine.serve().unwrap();
    for t in 0..3 {
        server.infer(t, pseudo_input(t)).unwrap();
    }

    let ids = engine.tenant_ids();
    engine.evict(ids[1]).unwrap();
    engine.redeploy(&server).unwrap();
    let specs = server.tenant_specs();
    assert_eq!(specs.len(), 2);
    assert_eq!(specs[0].name, "t0");
    assert_eq!(specs[1].name, "t2", "later slot shifted down");
    // Old slot 2 is now slot 1; slot 2 no longer exists.
    server.infer(1, pseudo_input(9)).unwrap();
    assert!(server.infer(2, pseudo_input(9)).is_err());
}

/// Migration end to end on a running cluster: skewed load moves a
/// tenant, the hot swap re-routes it, and every tenant still serves.
#[test]
fn migration_hot_swaps_on_a_running_cluster() {
    let Some(dir) = artifacts_dir() else { return };
    let mut b = GacerEngine::builder()
        .devices(2)
        .search(quick_cfg())
        .artifacts(dir);
    for i in 0..4 {
        b = b
            .serving_tenant(format!("t{i}"), "tiny_cnn", policy())
            .unwrap();
    }
    let mut engine = b.build().unwrap();
    let cluster = engine.serve_cluster().unwrap();
    for t in 0..4 {
        cluster.infer(t, pseudo_input(t)).unwrap();
    }

    // Feed the cluster's own counters back, then add synthetic skew.
    engine.record_served(&cluster.served_counts()).unwrap();
    let hot_device = (0..2)
        .find(|&d| engine.placement().tenants_on(d).len() >= 2)
        .unwrap();
    for (slot, id) in engine.tenant_ids().into_iter().enumerate() {
        if engine.placement().tenants_on(hot_device).contains(&slot) {
            engine.record_requests(id, 50_000).unwrap();
        }
    }
    let migration = engine
        .maybe_migrate(&MigrationPolicy::default())
        .unwrap()
        .expect("skewed load migrates");
    let moved_slot = engine
        .tenant_ids()
        .iter()
        .position(|&id| id == migration.tenant)
        .unwrap();

    let from_d = engine.device_pool().index_of(migration.from).unwrap();
    let to_d = engine.device_pool().index_of(migration.to).unwrap();
    let route_before = cluster.route_of(moved_slot).unwrap();
    let touched = engine.redeploy_cluster(&cluster).unwrap();
    let route_after = cluster.route_of(moved_slot).unwrap();
    assert_eq!(route_before.0, from_d);
    assert_eq!(route_after.0, to_d, "routing follows the migration");
    assert!(touched.contains(&from_d) || touched.contains(&to_d));

    for t in 0..4 {
        let out = cluster.infer(t, pseudo_input(100 + t)).unwrap();
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
