//! Integration: budgeted anytime re-search and warm-started search
//! state, end to end — the online-serving requirements of
//! `docs/SEARCH.md` (ROADMAP: "warm-start caches across admit/evict
//! events and bound re-plan latency").

use gacer::engine::{GacerEngine, MigrationCost, MigrationPolicy};
use gacer::gpu::SimOptions;
use gacer::models::zoo;
use gacer::plan::{DeploymentPlan, TenantSet};
use gacer::profile::{CostModel, Platform};
use gacer::search::{GacerSearch, SearchBudget, SearchConfig, SearchState};

fn quick_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 6,
        spatial_steps_per_level: 2,
        ..Default::default()
    }
}

fn tenant_set(names: &[&str]) -> TenantSet {
    TenantSet::new(zoo::build_combo(names), CostModel::new(Platform::titan_v()))
}

fn opts() -> SimOptions {
    SimOptions::for_platform(&Platform::titan_v())
}

#[test]
fn eval_budget_sweep_is_anytime_and_flags_truncation() {
    // An admit-shaped seed: the searched 3-tenant plan grown by one
    // tenant, re-searched under a sweep of evaluation budgets.
    let ts = tenant_set(&["R50", "V16", "M3"]);
    let searched = GacerSearch::new(&ts, opts(), quick_cfg()).run();
    assert!(!searched.truncated);

    let grown = tenant_set(&["R50", "V16", "M3", "R18"]);
    let mut seed = searched.plan.clone();
    seed.push_tenant(
        grown.tenants[3].len(),
        seed.pointers.pointers_per_tenant(),
    );
    let seed_obj = grown.simulate(&seed, opts()).objective();

    let mut last_obj = f64::INFINITY;
    for evals in [4usize, 16, 64, 256] {
        let search = GacerSearch::new(&grown, opts(), quick_cfg())
            .budget(SearchBudget::evaluations(evals));
        let r = search.run_from(seed.clone()).unwrap();
        // (b) of the acceptance criteria: never worse than the seed,
        // truncation flagged while the budget binds.
        assert!(
            r.outcome.objective() <= seed_obj + 1e-6,
            "budget {evals}: {} > seed {seed_obj}",
            r.outcome.objective()
        );
        r.plan.validate(&grown.tenants).unwrap();
        assert_eq!(r.budget, SearchBudget::evaluations(evals));
        if evals == 4 {
            assert!(r.truncated, "4 evals cannot converge a 4-tenant re-search");
        }
        // Monotone-anytime: a larger budget never returns a worse plan.
        assert!(
            r.outcome.objective() <= last_obj + 1e-6,
            "budget {evals} regressed: {} > {last_obj}",
            r.outcome.objective()
        );
        last_obj = r.outcome.objective();
    }
}

#[test]
fn deadline_budget_truncates_gracefully() {
    // A 1-nanosecond deadline is exhausted before any optional work: the
    // search returns the seed (or the unregulated fallback) immediately,
    // still valid, still flagged.
    let ts = tenant_set(&["R50", "V16", "M3"]);
    let search = GacerSearch::new(&ts, opts(), quick_cfg())
        .budget(SearchBudget::deadline(std::time::Duration::from_nanos(1)));
    let r = search.run();
    assert!(r.truncated);
    assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
    r.plan.validate(&ts.tenants).unwrap();
}

#[test]
fn warm_research_reproduces_cold_plan_bit_for_bit() {
    let ts = tenant_set(&["Alex", "V16", "R18"]);
    let search = GacerSearch::new(&ts, opts(), quick_cfg());
    let mut state = SearchState::new();
    let cold = search.run_with_state(&mut state);
    // Nothing changed: the warm re-search short-circuits to the exact
    // cold result.
    let warm = search.run_from_state(cold.plan.clone(), &mut state).unwrap();
    assert_eq!(warm.plan, cold.plan, "bit-for-bit reproduction");
    assert_eq!(warm.outcome, cold.outcome);
    assert_eq!(warm.evaluations, 0);
    assert_eq!(warm.warm_hits, 3);
    // And it is idempotent: the state still short-circuits.
    let again = search.run_from_state(cold.plan.clone(), &mut state).unwrap();
    assert_eq!(again.plan, cold.plan);
    assert_eq!(again.evaluations, 0);
}

#[test]
fn stale_seed_arity_is_rejected_not_a_panic() {
    let ts = tenant_set(&["Alex", "V16", "R18"]);
    let search = GacerSearch::new(&ts, opts(), quick_cfg());
    // Too many tenants (seed predates an eviction)...
    assert!(matches!(
        search.run_from(DeploymentPlan::unregulated(4)),
        Err(gacer::Error::InvalidPlan(_))
    ));
    // ...too few (seed predates an admission)...
    assert!(matches!(
        search.run_from(DeploymentPlan::unregulated(2)),
        Err(gacer::Error::InvalidPlan(_))
    ));
    // ...and a matching seed works.
    assert!(search.run_from(DeploymentPlan::unregulated(3)).is_ok());
}

#[test]
fn engine_admit_under_budget_keeps_plans_valid_and_reuses_state() {
    // Spatial off keeps chunking empty so incumbent stream fingerprints
    // survive events deterministically.
    let cfg = SearchConfig { enable_spatial: false, ..quick_cfg() };
    let mut engine = GacerEngine::builder()
        .devices(2)
        .search(cfg)
        .replan_budget(SearchBudget::evaluations(40))
        .tenant(zoo::build_default("R50").unwrap())
        .tenant(zoo::build_default("V16").unwrap())
        .tenant(zoo::build_default("M3").unwrap())
        .tenant(zoo::build_default("R18").unwrap())
        .build()
        .unwrap();
    assert!(!engine.last_report().unwrap().truncated, "cold build unbudgeted");

    // A run of churn events, all budgeted: plans stay valid and never
    // regress past the unregulated fallback.
    let a = engine.admit(zoo::build_default("Alex").unwrap()).unwrap();
    let r = engine.last_report().unwrap().clone();
    assert!(r.warm_hits > 0, "admit re-search reuses the build's streams");
    assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
    engine.sharded_plan().validate(engine.tenants()).unwrap();

    engine.evict(a).unwrap();
    engine.sharded_plan().validate(engine.tenants()).unwrap();
    if let Some(r) = engine.last_report() {
        assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
    }

    // Telemetry accumulated from the budgeted events prices migration.
    let cost = engine.migration_cost(1.0);
    assert!(cost.replan_us > 0.0);
    assert!(MigrationPolicy::cost_aware(cost).cost.is_some());
}

#[test]
fn cost_gain_contrast_marginal_declined_large_migrates() {
    // The satellite contrast test at the engine level: identical
    // tenants, controlled demand skew.
    let mut engine = GacerEngine::builder()
        .devices(2)
        .search(quick_cfg())
        .tenant(zoo::build_default("R18").unwrap())
        .tenant(zoo::build_default("R18").unwrap())
        .tenant(zoo::build_default("R18").unwrap())
        .tenant(zoo::build_default("R18").unwrap())
        .build()
        .unwrap();
    let ids = engine.tenant_ids();
    let hot: Vec<usize> = engine.placement().tenants_on(0).to_vec();
    assert_eq!(hot.len(), 2, "identical tenants split 2/2");
    for (slot, id) in ids.iter().enumerate() {
        let n = if hot.contains(&slot) { 5_000 } else { 1_000 };
        engine.record_requests(*id, n).unwrap();
    }
    // The ratio rule would migrate this skew (ratio 5 > 2); a bill
    // larger than any achievable gain declines it.
    let weights = engine.observed_tenant_weights();
    assert!(MigrationPolicy::default()
        .propose(&weights, engine.placement())
        .is_some());
    let pricey = MigrationPolicy::cost_aware(MigrationCost {
        replan_us: f64::MAX / 8.0,
        swap_pause_us: 0.0,
        payback_windows: 1.0,
    });
    assert!(engine.maybe_migrate(&pricey).unwrap().is_none());
    // The same skew with an affordable bill migrates: gain is the
    // bottleneck reduction (3/5 of device 0's load in weight units).
    let gain = weights[hot[0]].min(weights[hot[1]]);
    let fair = MigrationPolicy::cost_aware(MigrationCost {
        replan_us: gain * 0.1,
        swap_pause_us: 0.0,
        payback_windows: 1.0,
    });
    let m = engine.maybe_migrate(&fair).unwrap().expect("large skew migrates");
    assert_eq!(m.from, gacer::profile::DeviceId(0));
    engine.sharded_plan().validate(engine.tenants()).unwrap();
}
