//! Integration: PJRT runtime over real AOT artifacts — numeric parity with
//! the JAX-recorded goldens (`artifacts/goldens.json`).
//!
//! Requires `make artifacts` to have run; tests skip (with a notice) when
//! the artifact directory is absent so a bare checkout still passes
//! `cargo test`.

use gacer::runtime::{load_params, Runtime};
use gacer::util::json::Json;

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping runtime integration test: run `make artifacts` first");
        None
    }
}

fn load_goldens() -> Json {
    let text = std::fs::read_to_string("artifacts/goldens.json").unwrap();
    Json::parse(&text).unwrap()
}

#[test]
fn manifest_loads_with_expected_families() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let m = rt.manifest();
    assert!(m.len() >= 20, "expected >=20 artifacts, got {}", m.len());
    let tiny = m.variants_of("tiny_cnn");
    assert!(tiny.contains_key(&1) && tiny.contains_key(&8) && tiny.contains_key(&32));
    assert!(!m.variants_of("linear").is_empty());
    assert!(!m.chunked_variants_of("linear_chunked").is_empty());
}

#[test]
fn linear_artifact_matches_jax_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let goldens = load_goldens();
    let g = goldens.get("linear_b4").expect("golden present");
    let x = g.get("x").unwrap().as_f32_vec().unwrap();
    let w = g.get("w").unwrap().as_f32_vec().unwrap();
    let b = g.get("b").unwrap().as_f32_vec().unwrap();
    let expect = g.get("y").unwrap().as_f32_vec().unwrap();

    let out = rt.execute_f32("linear_b4", &[&x, &w, &b]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), expect.len());
    for (a, e) in out[0].iter().zip(&expect) {
        assert!((a - e).abs() < 1e-3 + 1e-3 * e.abs(), "{a} vs {e}");
    }
}

#[test]
fn tiny_cnn_artifact_matches_jax_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let params = load_params(dir).unwrap();
    let goldens = load_goldens();
    let g = goldens.get("tiny_cnn_b2").expect("golden present");
    let x = g.get("x").unwrap().as_f32_vec().unwrap();
    let expect = g.get("y").unwrap().as_f32_vec().unwrap();

    let mut inputs: Vec<&[f32]> = vec![&x];
    for p in &params {
        inputs.push(p);
    }
    let out = rt.execute_f32("tiny_cnn_b2", &inputs).unwrap();
    assert_eq!(out[0].len(), expect.len());
    for (a, e) in out[0].iter().zip(&expect) {
        assert!((a - e).abs() < 1e-2 + 1e-3 * e.abs(), "{a} vs {e}");
    }
}

#[test]
fn chunked_linear_variants_agree_with_full() {
    // GACER's Eq. 5 on real compiled code: every chunked variant computes
    // the same function as the unchunked batch-32 linear.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let chunked = rt.manifest().chunked_variants_of("linear_chunked");
    assert!(!chunked.is_empty());

    // Build a deterministic input set.
    let x: Vec<f32> = (0..32 * 512).map(|i| ((i % 89) as f32) / 89.0 - 0.5).collect();
    let w: Vec<f32> = (0..512 * 128).map(|i| ((i % 53) as f32) / 530.0).collect();
    let b: Vec<f32> = (0..128).map(|i| (i as f32) / 128.0).collect();

    let mut reference: Option<Vec<f32>> = None;
    for ((batch, chunk), name) in chunked {
        assert_eq!(batch, 32);
        let out = rt.execute_f32(&name, &[&x, &w, &b]).unwrap();
        match &reference {
            None => reference = Some(out[0].clone()),
            Some(r) => {
                for (a, e) in out[0].iter().zip(r) {
                    assert!(
                        (a - e).abs() < 1e-3 + 1e-3 * e.abs(),
                        "chunk {chunk}: {a} vs {e}"
                    );
                }
            }
        }
    }
}

#[test]
fn executor_rejects_bad_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    // Wrong arity.
    assert!(rt.execute_f32("linear_b4", &[&[0.0f32][..]]).is_err());
    // Wrong length.
    let x = vec![0.0f32; 3];
    let w = vec![0.0f32; 512 * 128];
    let b = vec![0.0f32; 128];
    assert!(rt.execute_f32("linear_b4", &[&x, &w, &b]).is_err());
    // Unknown entry.
    assert!(rt.execute_f32("nonexistent", &[]).is_err());
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    assert_eq!(rt.compiled_count(), 0);
    rt.warmup(&["linear_b1", "linear_b2"]).unwrap();
    assert_eq!(rt.compiled_count(), 2);
    let x = vec![0.0f32; 512];
    let w = vec![0.0f32; 512 * 128];
    let b = vec![0.0f32; 128];
    rt.execute_f32("linear_b1", &[&x, &w, &b]).unwrap();
    assert_eq!(rt.compiled_count(), 2, "no recompilation");
}
