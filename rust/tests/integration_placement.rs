//! Interference-aware placement acceptance tests: for a heterogeneous
//! mix where two SM-pool-saturating tenants dominate, `LoadBalance`
//! happily co-locates them while `InterferenceAware` keeps them apart —
//! end to end through the placement, the bench comparison, and the
//! engine (initial placement + objective-consistent admission).

use gacer::bench_util::{compare_placements, interference_demo_mix, PlacementArm};
use gacer::engine::GacerEngine;
use gacer::plan::{Placement, PlacementObjective, TenantSet};
use gacer::profile::{CostModel, Platform};
use gacer::search::SearchConfig;

fn demo_set() -> TenantSet {
    let platform = Platform::titan_v();
    TenantSet::new(interference_demo_mix(&platform), CostModel::new(platform))
}

fn quick_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 1,
        rounds_per_level: 1,
        positions_per_coordinate: 4,
        spatial_steps_per_level: 1,
        ..Default::default()
    }
}

/// The mix's shape (slots 0/3 saturate the pool, 1/2 are light) plus the
/// weight ordering that makes LPT pair the saturating tenants.
#[test]
fn demo_mix_preconditions_hold() {
    let set = demo_set();
    assert_eq!(set.len(), 4);
    let weights: Vec<f64> = set
        .tenants
        .iter()
        .map(|d| set.cost.sequential_latency_us(d))
        .collect();
    // hi-a > lo-a > lo-b > hi-b: exactly the ordering that tricks LPT.
    assert!(weights[0] > weights[1]);
    assert!(weights[1] > weights[2]);
    assert!(weights[2] > weights[3]);
    // The two saturating tenants dominate occupancy: alone they are
    // interference-free, together they halve each other.
    let pair = set.cost.colocation_slowdown(&[&set.tenants[0], &set.tenants[3]]);
    assert!(pair > 1.8, "saturating pair slowdown = {pair}");
    let light = set.cost.colocation_slowdown(&[&set.tenants[1], &set.tenants[2]]);
    assert!(light < 1.05, "light pair slowdown = {light}");
}

#[test]
fn load_balance_colocates_but_interference_separates() {
    let set = demo_set();
    let lb = Placement::balanced(&set, 2);
    let ia = Placement::interference_aware(&set, 2);
    lb.validate(set.len()).unwrap();
    ia.validate(set.len()).unwrap();

    assert_eq!(
        lb.device_of(0),
        lb.device_of(3),
        "LPT pairs the two saturating tenants (the bug this PR prices)"
    );
    assert_ne!(
        ia.device_of(0),
        ia.device_of(3),
        "interference-aware places them on different devices"
    );

    let max = |v: Vec<f64>| v.into_iter().fold(0.0f64, f64::max);
    assert!(
        max(ia.predicted_slowdowns(&set)) < max(lb.predicted_slowdowns(&set)),
        "lower predicted max device slowdown"
    );
    assert!(max(ia.interference_scores(&set)) < max(lb.interference_scores(&set)));
}

#[test]
fn bench_comparison_reports_the_win() {
    // The bench_util experiment surface of the same acceptance check:
    // the LoadBalance-vs-InterferenceAware comparison must show a lower
    // predicted max device slowdown for the interference arm.
    let platform = Platform::titan_v();
    let arms = compare_placements(interference_demo_mix(&platform), &platform, 2);
    let (lb, ia) = (&arms[0], &arms[1]);
    assert_eq!(lb.objective, PlacementObjective::LoadBalance);
    assert_eq!(ia.objective, PlacementObjective::InterferenceAware);
    let together = |arm: &PlacementArm| {
        arm.per_device.iter().any(|d| {
            d.contains(&"hi-a".to_string()) && d.contains(&"hi-b".to_string())
        })
    };
    assert!(together(lb) && !together(ia));
    assert!(ia.max_slowdown() < lb.max_slowdown());
    assert!(ia.max_score_ms < lb.max_score_ms);
    // Every device's slowdown is a real multiplier.
    assert!(ia.slowdowns.iter().chain(&lb.slowdowns).all(|&s| s >= 1.0));
}

#[test]
fn engine_builds_objective_consistent_deployments() {
    let platform = Platform::titan_v();

    // Interference-aware engine: the saturating tenants end up apart.
    let mut b = GacerEngine::builder()
        .devices(2)
        .placement_objective(PlacementObjective::InterferenceAware)
        .search(quick_cfg());
    for dfg in interference_demo_mix(&platform) {
        b = b.tenant(dfg);
    }
    let mut engine = b.build().unwrap();
    assert_eq!(
        engine.placement_objective(),
        PlacementObjective::InterferenceAware
    );
    let ids = engine.tenant_ids();
    let d_hi_a = engine.device_of(ids[0]).unwrap();
    let d_hi_b = engine.device_of(ids[3]).unwrap();
    assert_ne!(d_hi_a, d_hi_b, "engine placement separates the pair");
    engine.sharded_plan().validate(engine.tenants()).unwrap();
    engine.plan().validate(engine.tenants()).unwrap();

    // Admission stays objective-consistent: the newcomer lands on the
    // interference-scored device and only that shard is re-searched.
    let newcomer = engine.tenants()[3].clone();
    let id = engine.admit(newcomer).unwrap();
    let device = engine.device_of(id).unwrap();
    assert_eq!(engine.last_searched_device(), Some(device));
    let expected = {
        // Recompute the admission decision the engine must have made.
        let set = demo_set();
        Placement::from_assignments(
            (0..2)
                .map(|d| {
                    (0..4)
                        .filter(|&s| {
                            engine.placement().tenants_on(d).contains(&s)
                        })
                        .collect()
                })
                .collect(),
        )
        .least_interfering(&set, &set.tenants[3])
    };
    assert_eq!(device, expected);
    engine.sharded_plan().validate(engine.tenants()).unwrap();

    // The default-objective engine reproduces the co-location.
    let mut b = GacerEngine::builder().devices(2).search(quick_cfg());
    for dfg in interference_demo_mix(&platform) {
        b = b.tenant(dfg);
    }
    let engine = b.build().unwrap();
    assert_eq!(engine.placement_objective(), PlacementObjective::LoadBalance);
    let ids = engine.tenant_ids();
    assert_eq!(
        engine.device_of(ids[0]).unwrap(),
        engine.device_of(ids[3]).unwrap(),
        "load balance still pairs them — the objectives genuinely differ"
    );
}
