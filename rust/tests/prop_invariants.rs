//! Property-based invariants over routing, batching, scheduling, and
//! simulator state, using the in-tree deterministic property harness
//! (`gacer::util::rng::check_property`; offline environment — no proptest
//! crate available, same discipline: generated cases + replayable seeds).

use std::time::{Duration, Instant};

use gacer::coordinator::{BatchPolicy, Batcher, PendingRequest};
use gacer::gpu::{GpuSim, SimOp, SimOptions};
use gacer::models::zoo;
use gacer::plan::{DeploymentPlan, Placement, TenantSet};
use gacer::profile::{CostModel, Platform};
use gacer::search::{GacerSearch, SearchBudget, SearchConfig, SearchState};
use gacer::temporal::PointerMatrix;
use gacer::util::rng::{check_property, Rng};

fn random_plan(rng: &mut Rng, tenants: &[gacer::dfg::Dfg]) -> DeploymentPlan {
    let mut plan = DeploymentPlan::unregulated(tenants.len());
    for (ti, d) in tenants.iter().enumerate() {
        // Random pointers.
        let n_ptr = rng.below(4);
        let mut ptrs = Vec::new();
        for _ in 0..n_ptr {
            if d.len() > 2 {
                ptrs.push(rng.range(1, d.len() - 1));
            }
        }
        plan.pointers.set_list(ti, ptrs);
        // Random chunkings over a few ops.
        for _ in 0..rng.below(4) {
            let op = &d.ops[rng.below(d.len())];
            if !op.chunkable() {
                continue;
            }
            // Random split: halves/quarters plus a remainder form.
            let piece = *rng.choose(&[1, 2, 4]);
            if piece >= op.batch {
                continue;
            }
            let mut list = vec![piece; op.batch / piece];
            let rem = op.batch % piece;
            if rem > 0 {
                list.push(rem);
            }
            plan.chunking[ti].insert(op.id, list);
        }
    }
    plan
}

#[test]
fn prop_random_plans_validate_and_conserve_batches() {
    // (a) any chunking list_B sums to B; compiled streams cover every op.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
    check_property("plan-batch-conservation", 40, |rng| {
        let plan = random_plan(rng, &tenants);
        plan.validate(&tenants).unwrap();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let streams = ts.compile(&plan);
        for (ti, d) in tenants.iter().enumerate() {
            // Per source op: sum of piece batches equals... we verify via
            // occupancy-op coverage: every op id appears at least once.
            for op in &d.ops {
                let covered = streams[ti]
                    .iter()
                    .flat_map(|st| st.pieces.iter())
                    .any(|p| p.source_op == op.id && p.class == op.kind.class());
                assert!(covered, "tenant {ti} op {} uncovered", op.id);
            }
        }
    });
}

#[test]
fn prop_schedule_is_permutation_respecting_intra_model_order() {
    // (b) simulated op records = exactly the compiled ops, and within a
    // stream source ops complete in DFG order.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&["Alex", "R18", "M3"]);
    check_property("schedule-permutation", 25, |rng| {
        let plan = random_plan(rng, &tenants);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let out = ts.simulate(&plan, SimOptions::for_platform(&platform).with_ops());
        let records = out.op_records.unwrap();
        let compiled = ts.compile(&plan);
        let n_pieces: usize =
            compiled.iter().flat_map(|s| s.iter().map(|st| st.pieces.len())).sum();
        assert_eq!(records.len(), n_pieces, "every piece executed exactly once");
        for ti in 0..tenants.len() {
            let mut last_end_per_source: Vec<(usize, f64)> = records
                .iter()
                .filter(|r| r.stream == ti && r.class != "chunk" && r.class != "concat")
                .map(|r| (r.source_op, r.end_us))
                .collect();
            last_end_per_source.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            // Completion order of source ops must be non-decreasing in id
            // once reduced to their final completion.
            let mut max_end = std::collections::HashMap::new();
            for (src, end) in &last_end_per_source {
                let e = max_end.entry(*src).or_insert(0.0f64);
                *e = e.max(*end);
            }
            let mut ends: Vec<(usize, f64)> = max_end.into_iter().collect();
            ends.sort_by_key(|(src, _)| *src);
            for pair in ends.windows(2) {
                assert!(
                    pair[1].1 >= pair[0].1 - 1e-9,
                    "tenant {ti}: op {} finished before op {}",
                    pair[1].0,
                    pair[0].0
                );
            }
        }
    });
}

#[test]
fn prop_simulator_never_exceeds_pool_in_useful_occupancy() {
    // (c) the utilization trace never reports more than S_GPU.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&["R50", "V16", "M3"]);
    check_property("pool-cap", 20, |rng| {
        let plan = random_plan(rng, &tenants);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let out = ts.simulate(&plan, SimOptions::for_platform(&platform).with_trace());
        for iv in out.trace.unwrap().intervals() {
            assert!(iv.occupancy <= 100.0 + 1e-9);
            assert!(iv.occupancy >= 0.0);
        }
    });
}

#[test]
fn prop_residue_identity_under_random_streams() {
    // R = S_GPU * makespan - used, for arbitrary synthetic streams.
    check_property("residue-identity", 50, |rng| {
        let n_streams = rng.range(1, 4);
        let streams: Vec<Vec<SimOp>> = (0..n_streams)
            .map(|_| {
                (0..rng.range(1, 12))
                    .map(|_| SimOp {
                        occupancy: rng.range(1, 100) as f64,
                        duration_us: rng.range(1, 500) as f64,
                        mem_util: rng.range(1, 100) as f64,
                        segment: 0,
                        source_op: 0,
                        class: "conv",
                    })
                    .collect()
            })
            .collect();
        let mut opts = SimOptions::for_platform(&Platform::titan_v());
        opts.record_trace = true;
        let out = GpuSim::new(opts).run(&streams);
        assert!(
            (out.residue - (100.0 * out.makespan_us - out.used_sm_time)).abs()
                < 1e-6 * out.makespan_us.max(1.0)
        );
        // Makespan bounds: at least the longest stream's critical path /
        // full-contention bound, at most the fully serialized sum.
        let total: f64 = streams
            .iter()
            .flat_map(|s| s.iter().map(|o| o.duration_us))
            .sum();
        assert!(out.makespan_us <= total * 3.0 + 1e-6);
        let longest: f64 = streams
            .iter()
            .map(|s| s.iter().map(|o| o.duration_us).sum::<f64>())
            .fold(0.0, f64::max);
        assert!(out.makespan_us >= longest - 1e-6);
    });
}

#[test]
fn prop_gacer_never_worse_than_unregulated() {
    // (d) the search's returned objective <= the unregulated objective.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    check_property("search-monotone", 6, |rng| {
        let names: Vec<&str> = (0..3)
            .map(|_| *rng.choose(&["Alex", "R18", "M3", "LSTM", "BST", "V16"]))
            .collect();
        let tenants: Vec<_> =
            names.iter().map(|n| zoo::build_default(n).unwrap()).collect();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let cfg = SearchConfig {
            max_pointers: 2,
            rounds_per_level: 1,
            positions_per_coordinate: 5,
            spatial_steps_per_level: 2,
            ..Default::default()
        };
        let r = GacerSearch::new(&ts, SimOptions::for_platform(&platform), cfg).run();
        assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
        r.plan.validate(&tenants).unwrap();
    });
}

#[test]
fn prop_budgeted_search_is_monotone_anytime() {
    // (d') budgeted search is monotone-anytime: for random seeds and
    // random eval budgets b < 2b, the returned objective is never worse
    // than the seed's, and never worse with the larger budget (eval
    // budgets are deterministic, so the larger run extends the smaller).
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
    let cfg = SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 5,
        spatial_steps_per_level: 2,
        ..Default::default()
    };
    check_property("budgeted-monotone-anytime", 10, |rng| {
        let seed = random_plan(rng, &tenants);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let opts = SimOptions::for_platform(&platform);
        let seed_obj = ts.simulate(&seed, opts).objective();
        let b = rng.range(3, 60);
        let mut objectives = Vec::new();
        for budget in [b, 2 * b] {
            let r = GacerSearch::new(&ts, opts, cfg)
                .budget(SearchBudget::evaluations(budget))
                .run_from(seed.clone())
                .unwrap();
            assert!(
                r.outcome.objective() <= seed_obj + 1e-6,
                "budget {budget}: {} > seed {seed_obj}",
                r.outcome.objective()
            );
            r.plan.validate(&tenants).unwrap();
            objectives.push(r.outcome.objective());
        }
        assert!(
            objectives[1] <= objectives[0] + 1e-6,
            "doubling the budget regressed: {} > {}",
            objectives[1],
            objectives[0]
        );
    });
}

#[test]
fn prop_warm_research_reproduces_cold_when_nothing_changed() {
    // (d'') for random tenant combos, a warm re-search seeded with the
    // cold search's own plan on an unchanged set reproduces that plan
    // bit-for-bit at zero evaluations.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let cfg = SearchConfig {
        max_pointers: 1,
        rounds_per_level: 1,
        positions_per_coordinate: 4,
        spatial_steps_per_level: 1,
        ..Default::default()
    };
    check_property("warm-reproduces-cold", 8, |rng| {
        let names: Vec<&str> = (0..rng.range(2, 4))
            .map(|_| *rng.choose(&["Alex", "R18", "M3", "LSTM", "V16"]))
            .collect();
        let tenants: Vec<_> =
            names.iter().map(|n| zoo::build_default(n).unwrap()).collect();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let search = GacerSearch::new(&ts, SimOptions::for_platform(&platform), cfg);
        let mut state = SearchState::new();
        let cold = search.run_with_state(&mut state);
        let warm = search.run_from_state(cold.plan.clone(), &mut state).unwrap();
        assert_eq!(warm.plan, cold.plan, "{names:?}: warm diverged from cold");
        assert_eq!(warm.evaluations, 0, "{names:?}: warm re-search did work");
        assert_eq!(warm.warm_hits, tenants.len());
    });
}

#[test]
fn prop_batcher_never_drops_or_duplicates() {
    // (e) across random push/drain interleavings every request id comes
    // out exactly once, in FIFO order per drain.
    check_property("batcher-no-drop-no-dup", 60, |rng| {
        let variants = vec![1, 2, 4, 8, 16];
        let policy = BatchPolicy::new(
            rng.range(1, 12),
            Duration::from_millis(rng.range(0, 4) as u64),
            variants,
        );
        let mut batcher = Batcher::new(policy);
        let mut pushed = 0u64;
        let mut drained: Vec<u64> = Vec::new();
        let t0 = Instant::now();
        for step in 0..rng.range(5, 40) {
            if rng.f64() < 0.6 {
                batcher.push(PendingRequest::detached_at(pushed, vec![0.0; 4], t0));
                pushed += 1;
            }
            if rng.f64() < 0.5 {
                let now = t0 + Duration::from_millis(step as u64);
                if let Some((variant, batch)) = batcher.drain(now) {
                    assert!(variant >= batch.len());
                    drained.extend(batch.iter().map(|r| r.id));
                }
            }
        }
        while let Some((_, batch)) = batcher.flush() {
            drained.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(drained.len() as u64, pushed, "drop/dup detected");
        let mut sorted = drained.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len() as u64, pushed);
        // FIFO overall (single consumer, ordered drains).
        assert!(drained.windows(2).all(|w| w[0] < w[1]), "out of order");
    });
}

#[test]
fn prop_interference_placement_is_a_deterministic_partition() {
    // (f) for random zoo subsets at random batches and device counts,
    // `Placement::interference_aware` always yields a valid partition and
    // is deterministic (same inputs → identical placement).
    let platform = Platform::titan_v();
    check_property("interference-placement-partition", 25, |rng| {
        let n_tenants = rng.range(1, 6);
        let tenants: Vec<gacer::dfg::Dfg> = (0..n_tenants)
            .map(|_| {
                let name = *rng.choose(&["Alex", "R18", "V16", "M3", "LSTM"]);
                let batch = *rng.choose(&[1, 2, 8, 32]);
                zoo::build(name, batch).unwrap()
            })
            .collect();
        let set = TenantSet::new(tenants, CostModel::new(platform));
        let n_devices = rng.range(1, 4);
        let p = Placement::interference_aware(&set, n_devices);
        p.validate(set.len()).unwrap();
        assert_eq!(p.n_devices(), n_devices);
        assert_eq!(
            p,
            Placement::interference_aware(&set, n_devices),
            "placement must be deterministic"
        );
        // Scores/slowdowns are well-formed multipliers.
        assert!(p.predicted_slowdowns(&set).iter().all(|&s| s >= 1.0));
        assert!(p.interference_scores(&set).iter().all(|&s| s >= 0.0));
    });
}

#[test]
fn prop_identical_tenants_degenerate_to_lpt_max_load() {
    // (g) with identical occupancy profiles the interference term cannot
    // discriminate: interference-aware placement must match LPT's
    // bottleneck load (the LoadBalance objective) exactly.
    let platform = Platform::titan_v();
    check_property("interference-degenerates-to-lpt", 15, |rng| {
        let n_tenants = rng.range(2, 8);
        let name = *rng.choose(&["R18", "Alex", "M3"]);
        let tenants: Vec<gacer::dfg::Dfg> = (0..n_tenants)
            .map(|i| {
                let mut d = zoo::build_default(name).unwrap();
                d.name = format!("{name}-{i}");
                d
            })
            .collect();
        let set = TenantSet::new(tenants, CostModel::new(platform));
        let n_devices = rng.range(2, 4);
        let ia = Placement::interference_aware(&set, n_devices);
        let lb = Placement::balanced(&set, n_devices);
        ia.validate(set.len()).unwrap();
        let max_load =
            |p: &Placement| p.loads(&set).into_iter().fold(0.0f64, f64::max);
        let (ia_max, lb_max) = (max_load(&ia), max_load(&lb));
        assert!(
            (ia_max - lb_max).abs() <= 1e-6 * lb_max.max(1.0),
            "identical tenants: interference max load {ia_max} vs LPT {lb_max}"
        );
    });
}

#[test]
fn prop_roofline_slowdown_invariants() {
    // (h) the two-dimensional roofline slowdown
    // (`CostModel::colocation_slowdown`): for random tenant groups mixing
    // zoo models with bandwidth-hog BatchNorm chains,
    //   * it dominates the occupancy-only model (a max over two axes can
    //     only see more contention),
    //   * it is bounded by the tenant count (each tenant demands at most
    //     100% of either axis),
    //   * a lone tenant (or an empty group) is free,
    //   * adding a co-tenant never reduces it (demand sums only grow),
    //   * it is deterministic.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    check_property("roofline-invariants", 25, |rng| {
        let n = rng.range(1, 6);
        let tenants: Vec<gacer::dfg::Dfg> = (0..n)
            .map(|i| {
                if rng.f64() < 0.4 {
                    // A bandwidth hog: ~96% of peak HBM bandwidth, floor
                    // SM occupancy — exercises the memory axis.
                    let mut d = gacer::dfg::Dfg::new(format!("bn-{i}"));
                    for j in 0..rng.range(1, 20) {
                        d.push(
                            gacer::dfg::OpKind::BatchNorm { elems: 56 * 56 * 256 },
                            8,
                            format!("bn{j}"),
                        );
                    }
                    d
                } else {
                    let name = *rng.choose(&["Alex", "R18", "V16", "M3", "LSTM"]);
                    let batch = *rng.choose(&[1, 2, 8, 32]);
                    zoo::build(name, batch).unwrap()
                }
            })
            .collect();
        let refs: Vec<&gacer::dfg::Dfg> = tenants.iter().collect();
        let roofline = cost.colocation_slowdown(&refs);
        let occ = cost.occupancy_slowdown(&refs);
        assert!(
            roofline >= occ - 1e-9,
            "memory-aware {roofline} below occupancy-only {occ}"
        );
        assert!(occ >= 1.0 - 1e-9);
        assert!(
            roofline <= n as f64 + 1e-9,
            "{n} tenants cannot slow each other {roofline}x"
        );
        if n < 2 {
            assert_eq!(roofline, 1.0, "a lone tenant contends with nobody");
        }
        assert_eq!(roofline, cost.colocation_slowdown(&refs), "must be deterministic");
        // Monotone in added co-tenants.
        let extra = zoo::build_default("R18").unwrap();
        let mut grown = refs.clone();
        grown.push(&extra);
        assert!(
            cost.colocation_slowdown(&grown) >= roofline - 1e-9,
            "adding a co-tenant reduced the slowdown"
        );
    });
}

#[test]
fn prop_memory_placement_is_a_deterministic_partition_within_capacity() {
    // (i) `Placement::memory_aware` mirrors the interference-placement
    // property: always a valid partition, deterministic, and — since
    // every zoo tenant's footprint is far under the 12 GB device — the
    // per-device HBM usage stays within capacity.
    let platform = Platform::titan_v();
    check_property("memory-placement-partition", 20, |rng| {
        let n_tenants = rng.range(1, 6);
        let tenants: Vec<gacer::dfg::Dfg> = (0..n_tenants)
            .map(|_| {
                let name = *rng.choose(&["Alex", "R18", "V16", "M3", "LSTM"]);
                let batch = *rng.choose(&[1, 2, 8, 32]);
                zoo::build(name, batch).unwrap()
            })
            .collect();
        let set = TenantSet::new(tenants, CostModel::new(platform));
        let n_devices = rng.range(1, 4);
        let p = Placement::memory_aware(&set, n_devices);
        p.validate(set.len()).unwrap();
        assert_eq!(p.n_devices(), n_devices);
        assert_eq!(
            p,
            Placement::memory_aware(&set, n_devices),
            "placement must be deterministic"
        );
        let capacity = set.cost.platform.hbm_bytes();
        assert!(p.hbm_usage(&set).iter().all(|&b| b <= capacity));
        assert!(p.predicted_slowdowns(&set).iter().all(|&s| s >= 1.0));
        assert!(p.memory_scores(&set).iter().all(|&s| s >= 0.0));
    });
}

#[test]
fn prop_pointer_matrix_segments_partition_the_dfg() {
    let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
    check_property("segments-partition", 40, |rng| {
        let k = rng.range(1, 12);
        let m = PointerMatrix::equal_segments(&tenants, k);
        for (i, d) in tenants.iter().enumerate() {
            let segs = m.segments_of(i, d.len());
            assert_eq!(segs[0].0, 0);
            assert_eq!(segs.last().unwrap().1, d.len());
            let covered: usize = segs.iter().map(|(s, e)| e - s).sum();
            assert_eq!(covered, d.len());
        }
    });
}

#[test]
fn prop_pool_placement_is_a_deterministic_partition_within_every_device() {
    // (j) heterogeneous pools: for random tenant sets on random MIXED
    // device pools, every pool-aware objective yields a valid partition,
    // is deterministic, prices slowdowns as well-formed multipliers with
    // each device's own cost model, and the memory-aware arm keeps every
    // device's HBM usage within THAT device's capacity (a 1080Ti bin is
    // smaller than the A100 beside it).
    use gacer::plan::PlacementObjective;
    use gacer::profile::DevicePool;
    let platforms = [
        Platform::titan_v(),
        Platform::p6000(),
        Platform::gtx_1080ti(),
        Platform::a100(),
        Platform::t4(),
    ];
    check_property("pool-placement-partition", 20, |rng| {
        let n_tenants = rng.range(1, 6);
        let tenants: Vec<gacer::dfg::Dfg> = (0..n_tenants)
            .map(|_| {
                let name = *rng.choose(&["Alex", "R18", "V16", "M3", "LSTM"]);
                let batch = *rng.choose(&[1, 2, 8, 32]);
                zoo::build(name, batch).unwrap()
            })
            .collect();
        let n_devices = rng.range(2, 5);
        let picks: Vec<Platform> =
            (0..n_devices).map(|_| *rng.choose(&platforms)).collect();
        let pool = DevicePool::from_platforms(picks.clone());
        let set = TenantSet::new(tenants, CostModel::new(picks[0]));
        for objective in [
            PlacementObjective::LoadBalance,
            PlacementObjective::InterferenceAware,
            PlacementObjective::MemoryAware,
        ] {
            let p = Placement::with_objective_pool(&set, &pool, objective);
            p.validate(set.len()).unwrap();
            assert_eq!(p.n_devices(), n_devices);
            assert_eq!(
                p,
                Placement::with_objective_pool(&set, &pool, objective),
                "{objective:?} on {} must be deterministic",
                pool.label()
            );
            assert!(p
                .predicted_slowdowns_pool(&set, &pool)
                .iter()
                .all(|&s| s >= 1.0));
            if objective == PlacementObjective::MemoryAware {
                for (d, &used) in p.hbm_usage(&set).iter().enumerate() {
                    assert!(
                        used <= pool.platform(d).hbm_bytes(),
                        "{} ({}) holds {used} B over its own capacity",
                        pool.id(d),
                        pool.platform(d).name
                    );
                }
            }
        }
    });
}

#[test]
fn prop_uniform_pool_is_bit_for_bit_the_homogeneous_path() {
    // (k) a pool of k identical platforms is sugar, not a fork: every
    // objective must return the EXACT placement of the `n_devices = k`
    // homogeneous path (the pool constructors short-circuit to it), so
    // existing single-platform deployments are unchanged by the pool
    // refactor.
    use gacer::plan::PlacementObjective;
    use gacer::profile::DevicePool;
    let platforms =
        [Platform::titan_v(), Platform::p6000(), Platform::a100(), Platform::t4()];
    check_property("uniform-pool-bit-for-bit", 15, |rng| {
        let platform = *rng.choose(&platforms);
        let n_tenants = rng.range(1, 6);
        let tenants: Vec<gacer::dfg::Dfg> = (0..n_tenants)
            .map(|_| {
                let name = *rng.choose(&["Alex", "R18", "V16", "M3", "LSTM"]);
                let batch = *rng.choose(&[1, 2, 8, 32]);
                zoo::build(name, batch).unwrap()
            })
            .collect();
        let k = rng.range(1, 5);
        let pool = DevicePool::from_platforms(vec![platform; k]);
        let set = TenantSet::new(tenants, CostModel::new(platform));
        for objective in [
            PlacementObjective::LoadBalance,
            PlacementObjective::InterferenceAware,
            PlacementObjective::MemoryAware,
        ] {
            let pooled = Placement::with_objective_pool(&set, &pool, objective);
            let sugared = Placement::with_objective(&set, k, objective);
            assert_eq!(
                pooled, sugared,
                "{objective:?} diverged on a uniform {} x{k} pool",
                platform.name
            );
            // And the uniform pool prices exactly like the flat model.
            assert_eq!(
                pooled.predicted_slowdowns_pool(&set, &pool),
                pooled.predicted_slowdowns(&set)
            );
        }
    });
}

#[test]
fn prop_single_device_pool_degenerates() {
    // (k') the `devices(1)` degenerate case through the pool path: one
    // device of any platform holds every tenant, the only placement a
    // 1-bin partition allows.
    use gacer::plan::PlacementObjective;
    use gacer::profile::DevicePool;
    let platforms =
        [Platform::titan_v(), Platform::gtx_1080ti(), Platform::a100(), Platform::t4()];
    check_property("single-device-pool", 10, |rng| {
        let platform = *rng.choose(&platforms);
        let tenants: Vec<gacer::dfg::Dfg> = (0..rng.range(1, 5))
            .map(|_| {
                let name = *rng.choose(&["Alex", "R18", "V16", "M3"]);
                zoo::build_default(name).unwrap()
            })
            .collect();
        let pool = DevicePool::from_platforms([platform]);
        let set = TenantSet::new(tenants, CostModel::new(platform));
        for objective in [
            PlacementObjective::LoadBalance,
            PlacementObjective::InterferenceAware,
            PlacementObjective::MemoryAware,
        ] {
            let p = Placement::with_objective_pool(&set, &pool, objective);
            p.validate(set.len()).unwrap();
            assert_eq!(p.n_devices(), 1);
            assert_eq!(p.tenants_on(0).len(), set.len());
        }
    });
}

#[test]
fn prop_calibration_corrections_stay_inside_the_clamp() {
    // (l) under arbitrary observation streams — including junk samples
    // (NaN, infinities, zeros, negatives) that must be dropped — every
    // correction a calibrator hands a decision is either exactly 1.0
    // (trust ramp not completed) or inside [min_correction,
    // max_correction], and the residual store never exceeds its bound.
    use gacer::calibrate::{CalibrationConfig, Calibrator};
    check_property("calibration-clamp", 30, |rng| {
        let cfg = CalibrationConfig {
            min_samples: rng.range(1, 6) as u32,
            alpha: 0.05 + 0.9 * rng.f64(),
            min_correction: 0.1 + 0.9 * rng.f64(),
            max_correction: 1.0 + 9.0 * rng.f64(),
            max_entries: rng.range(1, 12),
        };
        let mut c = Calibrator::new(cfg).unwrap();
        let platforms = ["TitanV", "A100", "T4"];
        for _ in 0..rng.range(1, 120) {
            let tenant = rng.below(6) as u64;
            let platform = *rng.choose(&platforms);
            let (predicted, observed) = if rng.f64() < 0.2 {
                // Junk the calibrator must refuse to fold in.
                *rng.choose(&[
                    (f64::NAN, 100.0),
                    (100.0, f64::NAN),
                    (0.0, 100.0),
                    (100.0, 0.0),
                    (-5.0, 100.0),
                    (100.0, f64::INFINITY),
                ])
            } else {
                (10.0 + 1e5 * rng.f64(), 10.0 + 1e5 * rng.f64())
            };
            c.observe(tenant, platform, predicted, observed);
            assert!(c.len() <= cfg.max_entries, "residual store exceeded its bound");
            for t in 0..6u64 {
                for p in &platforms {
                    let k = c.correction(t, p);
                    if c.is_trusted(t, p) {
                        assert!(
                            (cfg.min_correction..=cfg.max_correction).contains(&k),
                            "trusted correction {k} outside \
                             [{}, {}]",
                            cfg.min_correction,
                            cfg.max_correction
                        );
                    } else {
                        assert_eq!(k, 1.0, "untrusted pair must stay analytic");
                    }
                }
            }
        }
        for e in c.entries() {
            assert_eq!(e.trusted, e.samples >= cfg.min_samples);
            if e.trusted {
                assert!((cfg.min_correction..=cfg.max_correction).contains(&e.correction));
            } else {
                assert_eq!(e.correction, 1.0);
            }
        }
    });
}

#[test]
fn prop_calibration_is_deterministic_in_seed_and_order() {
    // (l') the calibrator is a pure fold: replaying the identical
    // observation sequence (same seed, same order) into a fresh
    // calibrator reproduces every residual, trust flag, and correction
    // bit-for-bit.
    use gacer::calibrate::{CalibrationConfig, Calibrator};
    check_property("calibration-deterministic", 25, |rng| {
        let cfg = CalibrationConfig {
            max_entries: rng.range(2, 16),
            ..CalibrationConfig::default()
        };
        let platforms = ["TitanV", "P6000", "A100"];
        let sequence: Vec<(u64, &str, f64, f64)> = (0..rng.range(1, 80))
            .map(|_| {
                (
                    rng.below(5) as u64,
                    *rng.choose(&platforms),
                    1.0 + 1e4 * rng.f64(),
                    1.0 + 1e4 * rng.f64(),
                )
            })
            .collect();
        let mut a = Calibrator::new(cfg).unwrap();
        let mut b = Calibrator::new(cfg).unwrap();
        for &(tenant, platform, predicted, observed) in &sequence {
            assert_eq!(
                a.observe(tenant, platform, predicted, observed),
                b.observe(tenant, platform, predicted, observed)
            );
        }
        assert_eq!(a.entries(), b.entries(), "same fold, different residuals");
        assert_eq!(a.observations(), b.observations());
        for &(tenant, platform, ..) in &sequence {
            assert_eq!(a.correction(tenant, platform), b.correction(tenant, platform));
        }
    });
}

#[test]
fn prop_zero_observation_calibration_never_changes_a_decision() {
    // (l'') the regression guard behind the trust ramp: an engine built
    // WITH the calibrator but fed no latency window takes bit-for-bit
    // the decisions of its analytic twin — placement, migration, replan,
    // and admission — for any number of observe windows.
    use gacer::bench_util::calibration_sim::calibration_is_noop_without_observations;
    check_property("calibration-zero-obs-identity", 3, |rng| {
        let windows = rng.range(1, 4);
        assert!(
            calibration_is_noop_without_observations(windows),
            "{windows} empty windows diverged from the analytic twin"
        );
    });
}

#[test]
fn prop_calibration_ewma_converges_monotonically_to_a_constant_bias() {
    // (l''') fed a constant multiplicative bias after arbitrary warmup
    // noise, the residual EWMA's error against that bias is
    // non-increasing every step and converges; once trusted, the
    // correction lands on the clamped bias.
    use gacer::calibrate::{CalibrationConfig, Calibrator};
    check_property("calibration-ewma-converges", 25, |rng| {
        let cfg = CalibrationConfig::default();
        let mut c = Calibrator::new(cfg).unwrap();
        let bias = 0.3 + 4.7 * rng.f64();
        let predicted = 50.0 + 1e4 * rng.f64();
        // Warmup noise: random ratios in [0.5, 2.5].
        for _ in 0..rng.below(10) {
            c.observe(7, "TitanV", predicted, predicted * (0.5 + 2.0 * rng.f64()));
        }
        let ratio_of = |c: &Calibrator| {
            c.entries()
                .iter()
                .find(|e| e.tenant == 7 && e.platform == "TitanV")
                .map(|e| e.ratio_ewma)
        };
        let mut err = ratio_of(&c).map(|r| (r - bias).abs());
        for _ in 0..80 {
            assert!(c.observe(7, "TitanV", predicted, predicted * bias));
            let next = (ratio_of(&c).unwrap() - bias).abs();
            if let Some(prev) = err {
                assert!(
                    next <= prev + 1e-12,
                    "EWMA error grew under a constant bias: {next} > {prev}"
                );
            }
            err = Some(next);
        }
        // 80 folds of alpha=0.3 shrink any warmup error below 1e-9.
        assert!(err.unwrap() < 1e-9, "EWMA failed to converge to the bias");
        assert!(c.is_trusted(7, "TitanV"));
        assert!(
            (c.correction(7, "TitanV")
                - bias.clamp(cfg.min_correction, cfg.max_correction))
            .abs()
                < 1e-9
        );
    });
}
