//! Concurrency stress: producers hammer the cluster request path while
//! hot swaps re-deploy tenants mid-traffic.
//!
//! Runs everywhere — the servers use the synthetic backend
//! (`ServerBackend::Synthetic`), so the full production pipeline
//! (routing, scheduler, batchers, SLO shedding, completion fabric, epoch
//! fences) is exercised without compiled artifacts or a GPU. The
//! synthetic output contract makes correctness *observable* per
//! response: `out[0]` echoes the request's marker (lost/duplicated/
//! cross-paired responses would break the echo) and `out[1]` carries the
//! serving tenant's `name_tag` (a response computed under the wrong
//! tenant's queue — e.g. routed to a stale slot across a swap — would
//! carry the wrong tag).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use gacer::coordinator::{
    name_tag, BatchPolicy, ClusterServer, Server, ServerBackend, ServerConfig,
    SyntheticModel, TenantSpec,
};
use gacer::engine::{Deployment, ShardedDeployment};
use gacer::profile::DeviceId;
use gacer::slo::{SloPolicy, Tier};
use gacer::Error;

fn tenant(name: &str) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        family: "synthetic".to_string(),
        policy: BatchPolicy::new(8, Duration::from_micros(300), vec![1, 2, 4, 8]),
        chunk: None,
    }
}

fn deployment(names: &[&str]) -> Deployment {
    Deployment { tenants: names.iter().map(|n| tenant(n)).collect(), config: ServerConfig::default() }
}

/// Tenants a/b/c on two devices; `b` migrates between the devices on
/// every swap while global slots stay `[a, b, c]`.
fn plan_b_on_device0() -> ShardedDeployment {
    ShardedDeployment {
        per_device: vec![deployment(&["a", "b"]), deployment(&["c"])],
        routing: vec![(0, 0), (0, 1), (1, 0)],
        device_ids: vec![DeviceId(0), DeviceId(1)],
    }
}

fn plan_b_on_device1() -> ShardedDeployment {
    ShardedDeployment {
        per_device: vec![deployment(&["a"]), deployment(&["c", "b"])],
        routing: vec![(0, 0), (1, 1), (1, 0)],
        device_ids: vec![DeviceId(0), DeviceId(1)],
    }
}

/// N producers per tenant submit uniquely marked requests in a closed
/// loop while the main thread alternates cluster-wide hot swaps that
/// migrate tenant `b` between the two devices. Every successful response
/// must echo its own marker and carry its own tenant's tag — no
/// response lost, duplicated, cross-paired, or served by a stale slot.
#[test]
fn hot_swap_under_fire_loses_and_misroutes_nothing() {
    let names = ["a", "b", "c"];
    let start = plan_b_on_device0();
    let cluster = ClusterServer::start_with_backend(
        ServerBackend::Synthetic(SyntheticModel::echo()),
        start.per_device.iter().map(|d| (d.tenants.clone(), d.config.clone())).collect(),
        start.routing.clone(),
    )
    .unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let mut producers = Vec::new();
    for (slot, name) in names.iter().enumerate() {
        for lane in 0..2u64 {
            let cluster = cluster.clone();
            let stop = Arc::clone(&stop);
            let expected_tag = name_tag(name);
            producers.push(std::thread::spawn(move || -> (u64, u64) {
                let (mut oks, mut i) = (0u64, 0u64);
                while !stop.load(Ordering::Relaxed) {
                    // Unique marker, exact in f32 (stays far below 2^24).
                    let marker = ((lane * 1_000_000 + i) % 1_000_000) as f32;
                    i += 1;
                    let out = cluster.infer(slot, vec![marker, 0.0]).unwrap_or_else(|e| {
                        panic!("tenant {slot} request {i} failed mid-swap: {e:?}")
                    });
                    assert_eq!(out[0], marker, "response paired with the wrong request");
                    assert_eq!(out[1], expected_tag, "response served by the wrong tenant");
                    oks += 1;
                }
                (oks, i)
            }));
        }
    }

    // Hot-swap `b` back and forth under fire.
    let mut swaps = 0u64;
    for round in 0..30 {
        let plan = if round % 2 == 0 { plan_b_on_device1() } else { plan_b_on_device0() };
        let touched = cluster.apply(plan).unwrap();
        assert_eq!(touched, vec![0, 1], "both devices change on every migration");
        swaps += 1;
        std::thread::sleep(Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);

    let mut total_oks = 0u64;
    for p in producers {
        let (oks, submitted) = p.join().expect("producer panicked");
        assert_eq!(oks, submitted, "closed loop: every submission answered Ok");
        assert!(oks > 0, "producer made progress under swaps");
        total_oks += oks;
    }
    assert!(total_oks > 0);
    let epochs = cluster.epochs();
    assert!(
        epochs.iter().all(|&e| e >= swaps / 2),
        "every device fenced repeatedly: epochs {epochs:?} after {swaps} swaps"
    );
    // `b` ends where the last swap (round 29, odd) put it: device 0.
    assert_eq!(cluster.route_of(1), Some((0, 1)));
}

/// Producers hammer one synthetic server through tiny queue caps and an
/// unmeetable deadline: every submission must be answered exactly once —
/// an output row or a *typed* shed — and the server-side served/shed
/// counters must reconcile exactly with what clients observed.
#[test]
fn sheds_and_serves_reconcile_exactly_once_under_pressure() {
    let cfg = ServerConfig {
        slo: vec![
            SloPolicy::new(Tier::Standard).with_queue_cap(4),
            SloPolicy::new(Tier::Standard).with_deadline(Duration::from_nanos(1)),
        ],
        ..ServerConfig::default()
    };
    let server = Server::start_synthetic(
        SyntheticModel::echo(),
        vec![tenant("capped"), tenant("doomed")],
        cfg,
    )
    .unwrap();

    let mut workers = Vec::new();
    for w in 0..4u64 {
        let server = server.clone();
        workers.push(std::thread::spawn(move || -> (u64, u64, u64) {
            let (mut oks, mut sheds, mut submitted) = (0u64, 0u64, 0u64);
            for i in 0..400u64 {
                let tenant = (i % 2) as usize;
                let marker = ((w * 1000 + i) % 4000) as f32;
                submitted += 1;
                match server.infer(tenant, vec![marker, 0.0]) {
                    Ok(out) => {
                        assert_eq!(out[0], marker, "pairing survives shedding around it");
                        assert_eq!(
                            tenant, 0,
                            "the 1ns-deadline tenant can never be served"
                        );
                        oks += 1;
                    }
                    Err(Error::Overloaded(_) | Error::DeadlineExceeded(_)) => sheds += 1,
                    Err(other) => panic!("untyped failure under pressure: {other:?}"),
                }
            }
            (oks, sheds, submitted)
        }));
    }
    let (mut oks, mut sheds, mut submitted) = (0u64, 0u64, 0u64);
    for worker in workers {
        let (o, s, n) = worker.join().expect("worker panicked");
        oks += o;
        sheds += s;
        submitted += n;
    }
    assert_eq!(oks + sheds, submitted, "every request answered exactly once");
    assert!(oks > 0, "the capped tenant is served between sheds");
    assert!(sheds > 0, "the doomed tenant sheds");
    // Server-side accounting agrees with the clients exactly.
    assert_eq!(server.served_counts().iter().sum::<u64>(), oks);
    assert_eq!(server.shed_counts().iter().sum::<u64>(), sheds);
    assert_eq!(server.served_counts()[1], 0, "1ns deadline serves nothing");
}

/// Per-tenant FIFO survives the batched completion path: one producer
/// pins a tenant and submits ordered markers without waiting (open
/// loop); collecting the pending handles in submission order must yield
/// the markers in submission order.
#[test]
fn open_loop_submissions_complete_fifo_per_tenant() {
    let server = Server::start_synthetic(
        SyntheticModel::echo(),
        vec![tenant("x"), tenant("y")],
        ServerConfig::default(),
    )
    .unwrap();
    let mut lanes = Vec::new();
    for t in 0..2 {
        let server = server.clone();
        lanes.push(std::thread::spawn(move || {
            let pendings: Vec<_> = (0..500)
                .map(|i| server.submit(t, vec![i as f32, 0.0]).unwrap())
                .collect();
            for (i, p) in pendings.into_iter().enumerate() {
                let out = p.wait().unwrap();
                assert_eq!(out[0], i as f32, "tenant {t}: FIFO broken at {i}");
            }
        }));
    }
    for lane in lanes {
        lane.join().expect("lane panicked");
    }
    assert_eq!(server.served_counts(), vec![500, 500]);
}
