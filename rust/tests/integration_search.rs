//! Integration: the GACER joint search (Algorithm 1) end to end, with the
//! paper's §5.2 qualitative claims as acceptance criteria.

use gacer::baselines::{Baseline, BaselineKind};
use gacer::gpu::SimOptions;
use gacer::models::zoo;
use gacer::plan::TenantSet;
use gacer::profile::{CostModel, Platform};
use gacer::search::{GacerSearch, SearchConfig, SearchReport};

fn search(names: &[&str], platform: &Platform, cfg: SearchConfig) -> SearchReport {
    let cost = CostModel::new(*platform);
    let tenants = zoo::build_combo(names);
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    GacerSearch::new(&ts, SimOptions::for_platform(platform), cfg).run()
}

#[test]
fn gacer_beats_stream_parallel_on_every_combo() {
    let platform = Platform::titan_v();
    for combo in zoo::PAPER_COMBOS {
        let r = search(&combo, &platform, SearchConfig::default());
        assert!(
            r.outcome.makespan_us <= r.initial.makespan_us,
            "{}: search regressed",
            zoo::combo_label(&combo)
        );
    }
}

#[test]
fn gacer_speedup_vs_sequential_in_paper_band() {
    // Fig. 7: GACER lands at 1.37x-1.66x over CuDNN-Seq (we accept a
    // slightly wider band for the substitute substrate).
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let mut in_band = 0;
    for combo in zoo::PAPER_COMBOS {
        let tenants = zoo::build_combo(&combo);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let seq = Baseline::new(&ts, SimOptions::for_platform(&platform))
            .run(BaselineKind::CudnnSeq);
        let r = search(&combo, &platform, SearchConfig::default());
        let speedup = seq.makespan_us / r.outcome.makespan_us;
        assert!(speedup > 1.2, "{}: {speedup}", zoo::combo_label(&combo));
        if (1.3..=2.1).contains(&speedup) {
            in_band += 1;
        }
    }
    assert!(in_band >= 4, "only {in_band}/5 combos in band");
}

#[test]
fn spatial_arm_helps_heavy_workload_combo() {
    // §5.2: spatial regulation shines on R50+V16+M3 (large operator
    // workloads).
    let platform = Platform::titan_v();
    let r = search(&["R50", "V16", "M3"], &platform, SearchConfig::spatial_only());
    assert!(
        r.outcome.makespan_us < r.initial.makespan_us * 0.99,
        "spatial-only should improve the heavy combo: {} -> {}",
        r.initial.makespan_us,
        r.outcome.makespan_us
    );
}

#[test]
fn temporal_arm_helps_many_operator_combo() {
    // §5.2: temporal regulation shines on R101+D121+M3 (most layers).
    let platform = Platform::titan_v();
    let r = search(&["R101", "D121", "M3"], &platform, SearchConfig::temporal_only());
    assert!(
        r.outcome.makespan_us < r.initial.makespan_us * 0.995,
        "temporal-only should improve the deep combo: {} -> {}",
        r.initial.makespan_us,
        r.outcome.makespan_us
    );
}

#[test]
fn joint_no_worse_than_either_arm() {
    let platform = Platform::titan_v();
    for combo in [["R50", "V16", "M3"], ["R101", "D121", "M3"]] {
        let joint = search(&combo, &platform, SearchConfig::default());
        let spatial = search(&combo, &platform, SearchConfig::spatial_only());
        let temporal = search(&combo, &platform, SearchConfig::temporal_only());
        assert!(joint.outcome.makespan_us <= spatial.outcome.makespan_us * 1.02);
        assert!(joint.outcome.makespan_us <= temporal.outcome.makespan_us * 1.02);
    }
}

#[test]
fn gacer_utilization_beats_stream_parallel() {
    // Fig. 8: ~40% utilization enhancement over Stream-Parallel on the
    // deep combo (we assert a meaningful improvement).
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&["R101", "D121", "M3"]);
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let sp = Baseline::new(&ts, SimOptions::for_platform(&platform))
        .run(BaselineKind::StreamParallel);
    let r = search(&["R101", "D121", "M3"], &platform, SearchConfig::default());
    assert!(
        r.outcome.avg_utilization > sp.avg_utilization,
        "GACER util {} vs SP {}",
        r.outcome.avg_utilization,
        sp.avg_utilization
    );
}

#[test]
fn search_report_is_internally_consistent() {
    let platform = Platform::titan_v();
    let r = search(&["Alex", "V16", "R18"], &platform, SearchConfig::default());
    assert!(r.evaluations > 0);
    assert!(!r.level_best.is_empty());
    assert!(r.speedup_vs_initial() >= 1.0);
    // level_best[0] is the |P|=0 objective; the chosen plan's objective
    // cannot exceed it.
    assert!(r.outcome.objective() <= r.level_best[0] + 1e-6);
}

#[test]
fn search_works_on_two_and_four_tenant_sets() {
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    for names in [vec!["V16", "R18"], vec!["Alex", "V16", "R18", "M3"]] {
        let tenants: Vec<_> =
            names.iter().map(|n| zoo::build_default(n).unwrap()).collect();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let r = GacerSearch::new(
            &ts,
            SimOptions::for_platform(&platform),
            SearchConfig::default(),
        )
        .run();
        r.plan.validate(&tenants).unwrap();
        assert!(r.outcome.makespan_us <= r.initial.makespan_us);
    }
}

#[test]
fn search_cost_scales_roughly_linearly_in_rounds() {
    // Table 4's shape: wall time grows with the evaluation budget.
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&["R34", "LSTM", "BST"]);
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let small = SearchConfig { rounds_per_level: 1, ..Default::default() };
    let large = SearchConfig { rounds_per_level: 6, ..Default::default() };
    let e1 = GacerSearch::new(&ts, SimOptions::for_platform(&platform), small)
        .run()
        .evaluations;
    let e2 = GacerSearch::new(&ts, SimOptions::for_platform(&platform), large)
        .run()
        .evaluations;
    assert!(e2 >= e1, "evals {e1} -> {e2}");
}
