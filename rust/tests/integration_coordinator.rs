//! Integration: the serving coordinator over real artifacts — multi-tenant
//! batched inference with correct per-request routing.
//!
//! Requires `make artifacts`; skips with a notice when absent.

use std::sync::Arc;
use std::time::Duration;

use gacer::coordinator::{BatchPolicy, Server, ServerConfig, TenantSpec};
use gacer::runtime::{load_params, Runtime};

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping coordinator integration test: run `make artifacts` first");
        None
    }
}

fn tenant(name: &str, chunk: Option<usize>) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        family: "tiny_cnn".to_string(),
        policy: BatchPolicy::new(4, Duration::from_millis(1), vec![1, 2, 4, 8, 16, 32]),
        chunk,
    }
}

fn pseudo_input(seed: usize) -> Vec<f32> {
    (0..32 * 32 * 3)
        .map(|k| (((seed * 131 + k) % 97) as f32 / 97.0) - 0.5)
        .collect()
}

#[test]
fn server_answers_each_request_with_its_own_row() {
    let Some(dir) = artifacts_dir() else { return };
    // Ground truth via the runtime directly.
    let rt = Runtime::new(dir).unwrap();
    let params = load_params(dir).unwrap();
    let x0 = pseudo_input(0);
    let x1 = pseudo_input(1);
    let mut inputs: Vec<&[f32]> = vec![&x0];
    for p in &params {
        inputs.push(p);
    }
    let y0 = rt.execute_f32("tiny_cnn_b1", &inputs).unwrap()[0].clone();
    drop(rt);

    let server =
        Server::start(dir, vec![tenant("a", None), tenant("b", None)], ServerConfig::default())
            .unwrap();
    let out0 = server.infer(0, x0.clone()).unwrap();
    let out1 = server.infer(1, x1.clone()).unwrap();
    assert_eq!(out0.len(), 10);
    assert_eq!(out1.len(), 10);
    // Request 0's row matches the direct single-batch execution (batching
    // must not mix rows up).
    for (a, e) in out0.iter().zip(&y0) {
        assert!((a - e).abs() < 1e-3 + 1e-3 * e.abs(), "{a} vs {e}");
    }
    assert!(out0.iter().zip(&out1).any(|(a, b)| (a - b).abs() > 1e-6));
}

#[test]
fn concurrent_clients_all_get_answers() {
    let Some(dir) = artifacts_dir() else { return };
    let server = Arc::new(
        Server::start(
            dir,
            vec![tenant("a", Some(2)), tenant("b", None), tenant("c", None)],
            ServerConfig { issue_order: vec![2, 0, 1], ..Default::default() },
        )
        .unwrap(),
    );
    let mut handles = Vec::new();
    for t in 0..3 {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            for i in 0..10 {
                let out = server.infer(t, pseudo_input(t * 100 + i)).unwrap();
                assert_eq!(out.len(), 10);
                assert!(out.iter().all(|v| v.is_finite()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn chunked_tenant_matches_unchunked_numerically() {
    // GACER's spatial knob on the real path must not change results.
    let Some(dir) = artifacts_dir() else { return };
    let chunked =
        Server::start(dir, vec![tenant("a", Some(1))], ServerConfig::default()).unwrap();
    let plain = Server::start(dir, vec![tenant("a", None)], ServerConfig::default()).unwrap();
    let x = pseudo_input(7);
    let yc = chunked.infer(0, x.clone()).unwrap();
    let yp = plain.infer(0, x).unwrap();
    for (a, e) in yc.iter().zip(&yp) {
        assert!((a - e).abs() < 1e-3 + 1e-3 * e.abs(), "{a} vs {e}");
    }
}

#[test]
fn unknown_family_rejected_at_startup() {
    let Some(dir) = artifacts_dir() else { return };
    let bad = TenantSpec {
        name: "x".into(),
        family: "no_such_model".into(),
        policy: BatchPolicy::new(4, Duration::from_millis(1), vec![1]),
        chunk: None,
    };
    assert!(Server::start(dir, vec![bad], ServerConfig::default()).is_err());
}
