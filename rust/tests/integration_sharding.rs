//! Integration: the multi-GPU sharded deployment pipeline — placement →
//! per-device search → `ShardedDeploymentPlan` → per-device lowering —
//! plus the placement edge cases (degenerate single device, more devices
//! than tenants, emptying a device) and the acceptance criterion that
//! tenant churn re-searches only the affected shard.
//!
//! Everything here runs on the simulator substrate; no artifacts needed.

use gacer::models::zoo;
use gacer::prelude::*;

fn quick_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 5,
        spatial_steps_per_level: 2,
        ..Default::default()
    }
}

fn sharded_engine(names: &[&str], devices: usize) -> GacerEngine {
    let mut b = GacerEngine::builder().devices(devices).search(quick_cfg());
    for n in names {
        b = b.tenant(zoo::build_default(n).unwrap());
    }
    b.build().unwrap()
}

// ---- placement edge cases ----

#[test]
fn one_device_degenerates_to_single_plan_behavior() {
    // devices(1) must reproduce today's single-plan pipeline exactly:
    // one shard owning every slot, merged view == the shard.
    let engine = sharded_engine(&["Alex", "V16", "R18"], 1);
    assert_eq!(engine.n_devices(), 1);
    assert_eq!(engine.placement().tenants_on(0), &[0, 1, 2]);
    assert_eq!(engine.sharded_plan().shards.len(), 1);
    assert_eq!(engine.plan(), &engine.sharded_plan().shards[0]);
    engine.plan().validate(engine.tenants()).unwrap();

    // And it matches the plain (non-sharded) default builder's shape.
    let classic = {
        let mut b = GacerEngine::builder().search(quick_cfg());
        for n in ["Alex", "V16", "R18"] {
            b = b.tenant(zoo::build_default(n).unwrap());
        }
        b.build().unwrap()
    };
    // Same tenant set, same deterministic search: identical plans.
    assert_eq!(engine.plan(), classic.plan());
    assert_eq!(
        engine.simulate().makespan_us,
        classic.simulate().makespan_us
    );
}

#[test]
fn more_devices_than_tenants_leaves_devices_idle() {
    let engine = sharded_engine(&["Alex", "M3"], 4);
    engine.sharded_plan().validate(engine.tenants()).unwrap();
    let occupied: Vec<usize> = (0..4)
        .filter(|&d| !engine.placement().tenants_on(d).is_empty())
        .collect();
    assert_eq!(occupied.len(), 2, "each tenant alone on a device");
    // Idle devices: empty shard plans, no reports, zero simulated load.
    for d in 0..4 {
        if occupied.contains(&d) {
            assert!(engine.device_reports()[d].is_some());
        } else {
            assert!(engine.device_reports()[d].is_none());
            assert_eq!(engine.sharded_plan().shards[d].chunking.len(), 0);
            assert_eq!(engine.simulate_devices()[d].makespan_us, 0.0);
        }
    }
}

#[test]
fn evicting_the_last_tenant_on_a_device_empties_it() {
    let mut engine = sharded_engine(&["V16", "M3"], 2);
    let ids = engine.tenant_ids();
    let d_v16 = engine.device_of(ids[0]).unwrap();
    let d_m3 = engine.device_of(ids[1]).unwrap();
    assert_ne!(d_v16, d_m3);

    let survivor_shard = engine.sharded_plan().shards[d_m3].clone();
    engine.evict(ids[0]).unwrap();

    assert_eq!(engine.len(), 1);
    assert!(engine.placement().tenants_on(d_v16).is_empty());
    assert!(engine.device_reports()[d_v16].is_none());
    assert_eq!(engine.last_searched_device(), Some(d_v16));
    // The surviving device kept its searched plan bit-for-bit.
    assert_eq!(engine.sharded_plan().shards[d_m3], survivor_shard);
    engine.sharded_plan().validate(engine.tenants()).unwrap();

    // Evicting the final tenant empties the whole deployment cleanly.
    let ids = engine.tenant_ids();
    engine.evict(ids[0]).unwrap();
    assert!(engine.is_empty());
    engine.sharded_plan().validate(engine.tenants()).unwrap();
    assert!(engine.last_report().is_none());
}

#[test]
fn sharded_plan_validate_rejects_overlap_and_missing() {
    let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
    let placement = Placement::from_assignments(vec![vec![0, 2], vec![1]]);
    let good = ShardedDeploymentPlan::unregulated(placement);
    good.validate(&tenants).unwrap();

    // Overlapping assignment: slot 1 on both devices.
    let mut bad = good.clone();
    bad.placement = Placement::from_assignments(vec![vec![0, 1, 2], vec![1]]);
    assert!(matches!(bad.validate(&tenants), Err(Error::InvalidPlan(_))));

    // Missing assignment: slot 2 on no device.
    let mut bad = good.clone();
    bad.placement = Placement::from_assignments(vec![vec![0], vec![1]]);
    assert!(matches!(bad.validate(&tenants), Err(Error::InvalidPlan(_))));

    // Shard/device arity mismatch.
    let mut bad = good.clone();
    bad.shards.push(DeploymentPlan::unregulated(0));
    assert!(bad.validate(&tenants).is_err());

    // Per-shard plan contents are still validated (bad pointer range in
    // device 1's shard, expressed in local indices).
    let mut bad = good.clone();
    bad.shards[1].pointers.set_list(0, vec![10_000]);
    assert!(bad.validate(&tenants).is_err());
}

// ---- acceptance: devices(2) end to end ----

#[test]
fn two_device_engine_meets_the_acceptance_criteria() {
    // GacerEngine::builder().devices(2) produces a ShardedDeploymentPlan
    // that validates...
    let mut engine = sharded_engine(&["R50", "V16", "R18", "M3"], 2);
    engine.sharded_plan().validate(engine.tenants()).unwrap();
    assert_eq!(engine.sharded_plan().n_devices(), 2);
    assert!(!engine.placement().tenants_on(0).is_empty());
    assert!(!engine.placement().tenants_on(1).is_empty());

    // ...whose per-device searches are never worse than unregulated...
    for report in engine.device_reports().iter().flatten() {
        assert!(report.outcome.objective() <= report.initial.objective() + 1e-6);
    }

    // ...and admit re-searches ONLY the affected shard...
    let before = engine.sharded_plan().clone();
    let id = engine.admit(zoo::build_default("Alex").unwrap()).unwrap();
    let device = engine.device_of(id).unwrap();
    let other = 1 - device;
    assert_eq!(engine.last_searched_device(), Some(device));
    assert_eq!(
        engine.sharded_plan().shards[other], before.shards[other],
        "admit must not re-search the unaffected shard"
    );
    engine.sharded_plan().validate(engine.tenants()).unwrap();

    // ...as does evict.
    let before = engine.sharded_plan().clone();
    engine.evict(id).unwrap();
    assert_eq!(engine.last_searched_device(), Some(device));
    assert_eq!(
        engine.sharded_plan().shards[other], before.shards[other],
        "evict must not re-search the unaffected shard"
    );
    engine.sharded_plan().validate(engine.tenants()).unwrap();
}

#[test]
fn sharded_lowering_yields_independent_per_device_configs() {
    // Serving tenants lower per device: each device's issue order is a
    // permutation of ITS OWN tenants, and the routing table covers every
    // global slot exactly once. (Uses the tiny_cnn serving proxy; no
    // artifacts are needed to *lower*, only to *start* servers.)
    use gacer::coordinator::{BatchPolicy, ClusterServer};
    use std::time::Duration;

    let policy = BatchPolicy::new(8, Duration::from_millis(1), vec![1, 2, 4, 8]);
    let mut b = GacerEngine::builder().devices(2).search(quick_cfg());
    for i in 0..4 {
        b = b
            .serving_tenant(format!("t{i}"), "tiny_cnn", policy.clone())
            .unwrap();
    }
    let engine = b.build().unwrap();

    // Lowering requires a manifest only through family_variants; fake the
    // variant sets by lowering through the public per-plan API instead.
    let sharded = engine.sharded_plan();
    let mut sizes = Vec::new();
    for d in 0..2 {
        let tenants: Vec<Dfg> = engine
            .placement()
            .tenants_on(d)
            .iter()
            .map(|&s| engine.tenants()[s].clone())
            .collect();
        let specs: Vec<(String, String, BatchPolicy)> = tenants
            .iter()
            .map(|t| (t.name.clone(), "tiny_cnn".to_string(), policy.clone()))
            .collect();
        let variants = vec![vec![1, 2, 4, 8]; tenants.len()];
        let dep = gacer::engine::lower_plan(
            &sharded.shards[d],
            &tenants,
            &specs,
            &variants,
            Duration::from_micros(200),
        )
        .unwrap();
        // The per-device issue order is a permutation of 0..n_local.
        let mut order = dep.config.issue_order.clone();
        order.sort_unstable();
        let expect: Vec<usize> = (0..tenants.len()).collect();
        assert_eq!(order, expect, "device {d} issue order is a local permutation");
        dep.config.validate(tenants.len()).unwrap();
        sizes.push(tenants.len());
    }

    // The engine's routing table partitions the device slots.
    let routing: Vec<(usize, usize)> = (0..engine.len())
        .map(|slot| engine.placement().locate(slot).unwrap())
        .collect();
    ClusterServer::validate_routing(&routing, &sizes).unwrap();
}
