//! Integration: SLO regulation on the serving path — typed overload
//! shedding (queue caps, deadlines), per-tenant shed accounting, and the
//! latency-sample flow an engine observe loop drains.
//!
//! The serving tests require `make artifacts` and skip with a notice
//! when absent; the simulation test at the bottom runs everywhere.

use std::sync::Arc;
use std::time::Duration;

use gacer::coordinator::{BatchPolicy, Server, ServerConfig, TenantSpec};
use gacer::slo::{SloPolicy, Tier};
use gacer::Error;

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping SLO integration test: run `make artifacts` first");
        None
    }
}

fn tenant(name: &str, policy: BatchPolicy) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        family: "tiny_cnn".to_string(),
        policy,
        chunk: None,
    }
}

fn pseudo_input(seed: usize) -> Vec<f32> {
    (0..32 * 32 * 3)
        .map(|k| (((seed * 131 + k) % 97) as f32 / 97.0) - 0.5)
        .collect()
}

#[test]
fn expired_deadline_sheds_with_typed_error_and_is_counted() {
    let Some(dir) = artifacts_dir() else { return };
    // A 1ns deadline is unmeetable: every request is already past it by
    // the time a scheduling round looks at the queue, so each infer is
    // answered with the typed shed error (not a hang, not a panic).
    let policy = BatchPolicy::new(4, Duration::from_millis(1), vec![1, 2, 4, 8, 16, 32]);
    let cfg = ServerConfig {
        slo: vec![SloPolicy::new(Tier::Interactive)
            .with_deadline(Duration::from_nanos(1))],
        ..Default::default()
    };
    let server = Server::start(dir, vec![tenant("a", policy)], cfg).unwrap();
    for i in 0..3 {
        match server.infer(0, pseudo_input(i)) {
            Err(Error::DeadlineExceeded(msg)) => {
                assert!(msg.contains("deadline"), "unhelpful shed message: {msg}")
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
    assert_eq!(server.shed_counts(), vec![3], "every shed is counted");
}

#[test]
fn full_queue_sheds_concurrent_overload_per_tenant() {
    let Some(dir) = artifacts_dir() else { return };
    // Long batching window + queue cap 1: the first request occupies the
    // queue while the batcher waits out its timeout, so concurrent
    // arrivals overflow the cap and are answered with Overloaded.
    let policy = BatchPolicy::new(32, Duration::from_millis(300), vec![1, 2, 4, 8, 16, 32]);
    let capped = SloPolicy::new(Tier::Batch).with_queue_cap(1);
    let cfg = ServerConfig { slo: vec![capped, SloPolicy::default()], ..Default::default() };
    let server = Arc::new(
        Server::start(
            dir,
            vec![tenant("capped", policy.clone()), tenant("free", policy)],
            cfg,
        )
        .unwrap(),
    );
    let mut handles = Vec::new();
    for i in 0..6 {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || server.infer(0, pseudo_input(i))));
    }
    let mut ok = 0u64;
    let mut overloaded = 0u64;
    for h in handles {
        match h.join().unwrap() {
            Ok(out) => {
                assert_eq!(out.len(), 10);
                ok += 1;
            }
            Err(Error::Overloaded(msg)) => {
                assert!(msg.contains("queue"), "unhelpful shed message: {msg}");
                overloaded += 1;
            }
            Err(e) => panic!("unexpected error under overload: {e}"),
        }
    }
    assert_eq!(ok + overloaded, 6);
    assert!(ok >= 1, "the queued request must still be served");
    assert!(overloaded >= 1, "cap 1 under 6 concurrent clients must shed");
    // Shed accounting is per tenant: only the capped tenant's counter
    // moves, and it matches the client-visible rejections exactly.
    assert_eq!(server.shed_counts(), vec![overloaded, 0]);
}

#[test]
fn served_latency_samples_drain_once() {
    let Some(dir) = artifacts_dir() else { return };
    let policy = BatchPolicy::new(4, Duration::from_millis(1), vec![1, 2, 4, 8, 16, 32]);
    let server =
        Server::start(dir, vec![tenant("a", policy)], ServerConfig::default()).unwrap();
    for i in 0..4 {
        assert_eq!(server.infer(0, pseudo_input(i)).unwrap().len(), 10);
    }
    let samples = server.take_latencies();
    assert_eq!(samples.len(), 1);
    assert_eq!(samples[0].len(), 4, "one arrival->response sample per request");
    assert!(samples[0].iter().all(|&us| us.is_finite() && us > 0.0));
    // The drain is destructive — the next observe window starts empty.
    assert!(server.take_latencies()[0].is_empty());
    assert_eq!(server.shed_counts(), vec![0], "served requests are not sheds");
}

// ---- No artifacts needed below this line ------------------------------

#[test]
fn saturation_sim_holds_interactive_p99_only_under_regulation() {
    use gacer::bench_util::slo_sim::{run_slo_sim, saturated_mix, SloSimConfig};

    let cfg = SloSimConfig::default();
    let regulated = run_slo_sim(&saturated_mix(), &cfg, true);
    let fair = run_slo_sim(&saturated_mix(), &cfg, false);
    assert!(regulated.interactive_p99_us() <= cfg.target.target_us);
    assert!(fair.interactive_p99_us() > cfg.target.target_us);
    let batch_shed: u64 = regulated
        .tenants
        .iter()
        .filter(|t| t.tier == Tier::Batch)
        .map(|t| t.shed)
        .sum();
    assert!(batch_shed > 0, "regulation pays with batch sheds, not magic");
}
