//! Bench: regenerate Table 3 (spatial granularity cases for
//! V16(32) || R18(32)) — the spatial "sweet zone" evidence.

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    gacer::bench_util::experiments::table3();
    println!("\n[table3_spatial_granularity] wall time: {:.2?}", t0.elapsed());
}
