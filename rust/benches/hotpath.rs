//! Hot-path micro-benchmarks (criterion-lite: the offline environment has
//! no criterion crate, so this is a hand-rolled steady-state timer with
//! warmup + median-of-runs reporting).
//!
//! Targets the three L3 hot paths the search depends on:
//!   * cost-model lookups (memoized `W(O^B)`/`T(O^B)`) — the search's
//!     innermost dependency;
//!   * plan compile + simulate — the per-candidate evaluation;
//!   * one full coordinate-descent search — the Table 4 unit.

use std::hint::black_box;
use std::time::Instant;

use gacer::gpu::SimOptions;
use gacer::models::zoo;
use gacer::plan::{DeploymentPlan, TenantSet};
use gacer::profile::{CostModel, Platform};
use gacer::search::{GacerSearch, SearchConfig};

/// Run `f` for ~`target_ms`, report iterations/second and per-iter time.
fn bench<F: FnMut()>(name: &str, target_ms: u64, mut f: F) {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed().as_millis() < target_ms as u128 {
        f();
        iters += 1;
    }
    let el = t0.elapsed();
    let per = el.as_secs_f64() / iters as f64;
    let per_str = if per >= 1e-3 {
        format!("{:.3} ms", per * 1e3)
    } else {
        format!("{:.1} us", per * 1e6)
    };
    println!(
        "{name:<42} {iters:>8} iters   {per_str:>12}/iter   {:>10.0} iters/s",
        iters as f64 / el.as_secs_f64()
    );
}

fn main() {
    let platform = Platform::titan_v();
    let tenants = zoo::build_combo(&["R50", "V16", "M3"]);
    let deep = zoo::build_combo(&["R101", "D121", "M3"]);
    let opts = SimOptions::for_platform(&platform);

    println!("== hotpath micro-benchmarks (R50+V16+M3 unless noted) ==");

    // Cost-model lookups: cold vs memoized.
    bench("cost_model: cold build + full combo pricing", 1000, || {
        let cost = CostModel::new(platform);
        for d in &tenants {
            for op in &d.ops {
                black_box(cost.cost(op));
            }
        }
    });
    let cost = CostModel::new(platform);
    bench("cost_model: memoized full combo pricing", 1000, || {
        for d in &tenants {
            for op in &d.ops {
                black_box(cost.cost(op));
            }
        }
    });

    // Plan compile + simulate (the search's per-candidate evaluation).
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let plan = DeploymentPlan::unregulated(3);
    bench("evaluate: compile + simulate (343 ops)", 2000, || {
        black_box(ts.simulate(&plan, opts));
    });

    let cost_deep = CostModel::new(platform);
    let ts_deep = TenantSet::new(deep, cost_deep);
    let plan_deep = DeploymentPlan::unregulated(3);
    bench("evaluate: compile + simulate (900 ops, deep)", 2000, || {
        black_box(ts_deep.simulate(&plan_deep, opts));
    });

    // Full search (Table 4's unit).
    let cfg = SearchConfig::default();
    bench("search: full Algorithm 1 (default config)", 4000, || {
        black_box(GacerSearch::new(&ts, opts, cfg).run());
    });

    // Simulator throughput in simulated-op terms.
    let streams = ts.compile(&plan);
    let n_ops: usize = streams.iter().map(|s| s.len()).sum();
    let t0 = Instant::now();
    let mut evals = 0u64;
    while t0.elapsed().as_secs_f64() < 1.0 {
        black_box(gacer::gpu::GpuSim::new(opts).run_staged(&streams));
        evals += 1;
    }
    let ops_per_s = (evals as f64 * n_ops as f64) / t0.elapsed().as_secs_f64();
    println!("simulator throughput: {:.1}M simulated ops/s", ops_per_s / 1e6);
}
