//! Bench: calibration-constant sensitivity ablation — the paper-shape
//! ordering must survive the substitute substrate's free constants.

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    gacer::bench_util::experiments::ablation_sensitivity();
    println!("\n[ablation_sensitivity] wall time: {:.2?}", t0.elapsed());
}
