//! Bench: regenerate Table 4 (GACER search wall-time vs evaluation
//! budget, three combos).

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    gacer::bench_util::experiments::table4(3);
    println!("\n[table4_search_overhead] wall time: {:.2?}", t0.elapsed());
}
