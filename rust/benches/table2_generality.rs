//! Bench: regenerate Table 2 (GPU generality: absolute ms + speedups on
//! P6000 and 1080Ti for all five combos).

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    gacer::bench_util::experiments::table2();
    println!("\n[table2_generality] wall time: {:.2?}", t0.elapsed());
}
