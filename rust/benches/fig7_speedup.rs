//! Bench: regenerate Fig. 7 (runtime performance, 5 combos x 7 strategies,
//! Titan V, normalized to CuDNN-Seq) and time the full strategy sweep.

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    gacer::bench_util::experiments::fig7();
    println!("\n[fig7_speedup] wall time: {:.2?}", t0.elapsed());
}
