//! Bench: regenerate Fig. 9 (temporal granularity sweep: model-wise ->
//! segment-k -> operator-wise latency, three combos) — the temporal
//! "sweet zone" evidence.

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    gacer::bench_util::experiments::fig9();
    println!("\n[fig9_temporal_granularity] wall time: {:.2?}", t0.elapsed());
}
