#!/usr/bin/env python3
"""Enforce committed per-module line-coverage floors.

Reads the JSON export of `cargo llvm-cov report --json` (the
llvm.coverage.json.export format) and aggregates line coverage over the
source prefixes named in FLOORS. Exits non-zero when any module falls
below its floor, printing a table either way, so the CI coverage job is
a regression gate and not just a report.

The floors are deliberately modest: they exist to catch a module's tests
being deleted or skipped wholesale, not to chase a number. Raise a floor
when a module's coverage durably improves; never lower one to make a
red build green without discussing it in the PR.
"""

import json
import sys

# Module prefix (repo-relative) -> minimum line coverage, percent.
FLOORS = {
    "rust/src/calibrate/": 80.0,
    "rust/src/engine/": 55.0,
    "rust/src/plan.rs": 55.0,
}


def main() -> int:
    if len(sys.argv) != 2:
        print(f"usage: {sys.argv[0]} <llvm-cov-report.json>", file=sys.stderr)
        return 2
    with open(sys.argv[1], encoding="utf-8") as f:
        report = json.load(f)

    # One (covered, total) accumulator per floor prefix.
    acc = {prefix: [0, 0] for prefix in FLOORS}
    for export in report.get("data", []):
        for entry in export.get("files", []):
            filename = entry.get("filename", "")
            lines = entry.get("summary", {}).get("lines", {})
            for prefix, counts in acc.items():
                if prefix in filename:
                    counts[0] += int(lines.get("covered", 0))
                    counts[1] += int(lines.get("count", 0))

    failed = False
    print(f"{'module':<28} {'lines':>12} {'coverage':>9} {'floor':>7}")
    for prefix, floor in sorted(FLOORS.items()):
        covered, total = acc[prefix]
        if total == 0:
            print(f"{prefix:<28} {'-':>12} {'MISSING':>9} {floor:>6.1f}%")
            failed = True
            continue
        pct = 100.0 * covered / total
        verdict = "ok" if pct >= floor else "FAIL"
        print(
            f"{prefix:<28} {covered:>5}/{total:<6} {pct:>8.2f}% {floor:>6.1f}% {verdict}"
        )
        if pct < floor:
            failed = True
    if failed:
        print("coverage floor violated", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
